//! `igp` — leader binary: train iterative GPs, run the paper's experiment
//! suite, inspect configs.  See README.md for the full CLI reference.

use anyhow::Result;

use std::sync::Arc;

use igp::config::RunConfig;
use igp::coordinator::{Trainer, TrainerOptions};
use igp::estimator::EstimatorKind;
use igp::fault::FaultPlan;
use igp::operators::{BackendKind, KernelOperator, Precision, TiledOptions, XlaOperator};
use igp::serve::{ModelFleet, PredictionService, ServeOptions, StalenessPolicy};
use igp::solvers::SolverKind;
use igp::util::logging;

mod cli;
mod experiments;

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    match cmd.as_str() {
        "train" => cmd_train(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "exp" => experiments::dispatch(&args[1..]),
        "list-datasets" => {
            for s in igp::data::registry() {
                println!(
                    "{:<16} n={:<6} d={:<3} sigma={:<5} (paper n={})",
                    s.name, s.n, s.d, s.true_sigma, s.paper_n
                );
            }
            Ok(())
        }
        "info" => cmd_info(&args[1..]),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `igp help`)"),
    }
}

fn print_help() {
    println!(
        r#"igp — iterative Gaussian processes (NeurIPS 2024 reproduction)

USAGE:
    igp train [--config FILE] [--dataset D] [--solver cg|ap|sgd]
              [--estimator standard|pathwise] [--warm-start]
              [--backend dense|tiled|xla] [--tile N] [--shards S] [--threads N]
              [--probes S] [--rff M] [--online K] [--precision f32|f64]
              [--steps N] [--lr F] [--max-epochs N] [--seed N]
              [--artifacts DIR] [--out results.csv] [--chaos SPEC]
    igp serve [train flags] [--batch N] [--score in.csv [out.csv]]
              [--policy refuse|serve_stale|refresh_first] [--queue-cap N]
              [--deadline T] [--tenants N]
              train, then answer queries from the amortised pathwise
              posterior: --score reads query rows (d columns) from in.csv
              and writes mean,var per row (stdout if out.csv is omitted);
              without --score the held-out split is served and scored.
              --deadline routes the query through the request queue with
              logical deadline tick T; --queue-cap bounds queued rows;
              --policy picks the staleness policy for online arrivals;
              --tenants N trains N models (seed, seed+1, ...) and serves
              them as a fleet over ONE shared artifact cache, draining
              deadline-staggered requests earliest-deadline-first
    igp exp <id|all> [--out DIR] [--splits N] [--steps N]
              ids: table1 table7 fig1 fig3 fig4 fig5 fig6 fig7 fig9 fig10
    igp list-datasets
    igp info <config>        # print an artifact config's meta

BACKENDS:
    tiled  (default) matrix-free multi-threaded CPU backend, O(n*d) memory;
           knobs: --tile (block edge, default 256), --threads (0 = auto),
           --shards (row shards with per-shard panel caches, default 1;
           bitwise-identical results for every shard count)
    dense  pure-Rust oracle materialising H, O(n^2) memory (tiny n only)
    xla    compiled PJRT artifacts (needs `make artifacts` + xla feature)

ONLINE MODE:
    --online K replays the dataset in K arrival chunks and trains --steps
    outer steps after each arrival, carrying the warm-start store, probe
    randomness and optimiser state across arrivals (dense/tiled only).

PRECISION:
    --precision f32 runs the O(n^2) operator products in f32 with f64
    accumulation (CPU backends only): CG adds an iterative-refinement
    outer loop, and every solver verifies its answer with an f64 residual
    recomputation, falling back to the reference f64 path on drift.
    --precision f64 (default) is the bitwise-parity reference.

CHAOS (fault injection):
    --chaos "seed=N;SITE@STEP[xCOUNT];SITE~PROB" arms deterministic fault
    injection with supervised recovery (sites: panel probe shard precond
    solver cache checkpoint refresh).  Recoverable faults converge bitwise
    with the fault-free run, with the recovery cost metered in a trailing
    `recovery:` line.  Unarmed runs pay nothing.  See rust/README.md.
"#
    );
}

fn cmd_info(args: &[String]) -> Result<()> {
    let p = cli::Parser::new(args, &["artifacts"])?;
    let name = p.positional.first().map(String::as_str).unwrap_or("test");
    let dir = p.get("artifacts").unwrap_or("artifacts");
    let meta = igp::runtime::Meta::load(std::path::Path::new(dir).join(name).join("meta.txt").as_path())?;
    println!("{meta:#?}");
    Ok(())
}

/// Single source of the RunConfig -> TrainerOptions mapping so the plain
/// and online training paths cannot drift apart (`block` is only pinned
/// by the XLA artifact).
fn trainer_options(rc: &RunConfig, block: Option<usize>) -> Result<TrainerOptions> {
    Ok(TrainerOptions {
        solver: SolverKind::parse(&rc.solver)?,
        estimator: EstimatorKind::parse(&rc.estimator)?,
        warm_start: rc.warm_start,
        lr: rc.lr,
        tolerance: rc.tolerance,
        max_epochs: rc.max_epochs.map(|e| e as f64),
        block_size: block,
        seed: rc.seed,
        predict_every: Some(10),
        threads: rc.threads,
        precision: Precision::parse(&rc.precision)?,
        ..Default::default()
    })
}

/// Online data-arrival training: replay the dataset in `online_chunks`
/// arrivals, training `outer_steps` outer-loop steps after each one with
/// warm-carried coordinator state (`Trainer::extend_data`).
fn cmd_train_online(rc: &RunConfig, out_path: Option<&str>) -> Result<()> {
    let ds = igp::data::generate(&igp::data::spec(&rc.dataset)?);
    anyhow::ensure!(
        rc.online_chunks <= ds.spec.n,
        "--online {} exceeds the dataset's {} training rows",
        rc.online_chunks,
        ds.spec.n
    );
    let backend = BackendKind::parse(&rc.backend)?;
    let (base, chunks) = ds.replay_chunks(rc.online_chunks);
    let topts = TiledOptions { tile: rc.tile, threads: rc.threads };
    let mut op =
        igp::operators::make_cpu_backend(backend, &base, rc.probes, rc.rff, topts, rc.shards)?;
    let prec = Precision::parse(&rc.precision)?;
    if prec.is_f32() {
        op.set_precision(Precision::F32)?;
    }
    igp::info!(
        "backend: {} (online: {} arrivals of ~{} rows)",
        backend.name(),
        rc.online_chunks,
        ds.spec.n / rc.online_chunks
    );
    let opts = trainer_options(rc, None)?;
    let mut trainer = Trainer::new(opts, op, &base);
    let armed = arm_chaos(&mut trainer, rc)?;

    println!(
        "dataset={} solver={} estimator={} warm={} backend={} online_chunks={}",
        rc.dataset, rc.solver, rc.estimator, rc.warm_start, rc.backend, rc.online_chunks
    );
    println!(
        "{:>8} {:>7} {:>9} {:>11} {:>9} {:>9}",
        "arrival", "n", "epochs", "solver_secs", "rmse", "llh"
    );
    let mut rows = Vec::new();
    let mut arrival = 0usize;
    let mut total_epochs = 0.0;
    let mut report = |arrival: usize, n: usize, out: &igp::coordinator::TrainOutcome| {
        println!(
            "{arrival:>8} {n:>7} {:>9.1} {:>11.3} {:>9.4} {:>9.4}",
            out.total_epochs, out.solver_secs, out.final_metrics.rmse, out.final_metrics.llh
        );
        rows.push([
            arrival.to_string(),
            n.to_string(),
            out.total_epochs.to_string(),
            out.solver_secs.to_string(),
            out.final_metrics.rmse.to_string(),
            out.final_metrics.llh.to_string(),
        ]);
    };
    let out = trainer.run(rc.outer_steps)?;
    total_epochs += out.total_epochs;
    report(arrival, trainer.operator().n(), &out);
    for (x, y) in &chunks {
        arrival += 1;
        trainer.extend_data(x, y)?;
        let out = trainer.run(rc.outer_steps)?;
        total_epochs += out.total_epochs;
        report(arrival, trainer.operator().n(), &out);
    }
    println!("total: {total_epochs:.1} epochs across {} arrivals", rc.online_chunks);
    if armed {
        println!("recovery: {}", trainer.recovery_stats().summary());
    }

    if let Some(path) = out_path {
        let mut w = igp::util::csv::CsvWriter::create(
            path,
            &["arrival", "n", "epochs", "solver_secs", "rmse", "llh"],
        )?;
        for r in &rows {
            w.row(r)?;
        }
        w.flush()?;
        igp::info!("online telemetry written to {path}");
    }
    Ok(())
}

/// Option names (taking a value) shared by `train` and `serve`.
const TRAIN_VALUE_KEYS: &[&str] = &[
    "config", "dataset", "solver", "estimator", "steps", "lr", "max-epochs",
    "seed", "artifacts", "out", "tolerance", "backend", "tile", "shards",
    "threads", "probes", "rff", "online", "precision", "chaos",
];

/// Arm a fault plan on the trainer when the run config carries a chaos
/// spec.  Returns whether a plan was armed (gates the `recovery:` line —
/// unarmed runs print nothing and pay nothing).
fn arm_chaos(trainer: &mut Trainer, rc: &RunConfig) -> Result<bool> {
    match &rc.chaos {
        Some(spec) => {
            trainer.arm_faults(Arc::new(FaultPlan::parse(spec)?));
            igp::info!("chaos armed: {spec}");
            Ok(true)
        }
        None => Ok(false),
    }
}

/// Resolve a [`RunConfig`] from `--config` plus flag overrides — single
/// source for the `train` and `serve` commands so their training setups
/// cannot drift apart.
fn run_config_from_args(p: &cli::Parser) -> Result<RunConfig> {
    let mut rc = match p.get("config") {
        Some(path) => RunConfig::from_doc(&igp::config::parse_file(path)?)?,
        None => RunConfig::default(),
    };
    if let Some(v) = p.get("dataset") {
        rc.dataset = v.to_string();
    }
    if let Some(v) = p.get("solver") {
        rc.solver = v.to_string();
    }
    if let Some(v) = p.get("estimator") {
        rc.estimator = v.to_string();
    }
    if p.flag("warm-start") {
        rc.warm_start = true;
    }
    if let Some(v) = p.get_parsed::<usize>("steps")? {
        rc.outer_steps = v;
    }
    if let Some(v) = p.get_parsed::<f64>("lr")? {
        rc.lr = v;
    }
    if let Some(v) = p.get_parsed::<f64>("tolerance")? {
        rc.tolerance = v;
    }
    if let Some(v) = p.get_parsed::<usize>("max-epochs")? {
        rc.max_epochs = Some(v);
    }
    if let Some(v) = p.get_parsed::<u64>("seed")? {
        rc.seed = v;
    }
    if let Some(v) = p.get("artifacts") {
        rc.artifacts_dir = v.to_string();
    }
    if let Some(v) = p.get("backend") {
        rc.backend = v.to_string();
    }
    if let Some(v) = p.get_parsed::<usize>("tile")? {
        rc.tile = v;
    }
    if let Some(v) = p.get_parsed::<usize>("shards")? {
        rc.shards = v;
    }
    if let Some(v) = p.get_parsed::<usize>("threads")? {
        rc.threads = v;
    }
    if let Some(v) = p.get_parsed::<usize>("probes")? {
        rc.probes = v;
    }
    if let Some(v) = p.get_parsed::<usize>("rff")? {
        rc.rff = v;
    }
    if let Some(v) = p.get_parsed::<usize>("online")? {
        rc.online_chunks = v;
    }
    if let Some(v) = p.get("precision") {
        rc.precision = v.to_string();
    }
    if let Some(v) = p.get("chaos") {
        rc.chaos = Some(v.to_string());
    }
    rc.validate()?;
    Ok(rc)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let p = cli::Parser::new(args, TRAIN_VALUE_KEYS)?;
    let rc = run_config_from_args(&p)?;

    if rc.online_chunks > 1 {
        return cmd_train_online(&rc, p.get("out"));
    }

    let ds = igp::data::generate(&igp::data::spec(&rc.dataset)?);
    let backend = BackendKind::parse(&rc.backend)?;
    let (op, block): (Box<dyn KernelOperator>, Option<usize>) = match backend {
        BackendKind::Xla => {
            let rt = igp::runtime::Runtime::cpu()?;
            igp::info!("PJRT platform: {}", rt.platform());
            let model = rt.load_config(&rc.artifacts_dir, &rc.dataset)?;
            let b = model.meta.b;
            (Box::new(XlaOperator::new(model, &ds)), Some(b))
        }
        kind => {
            let topts = TiledOptions { tile: rc.tile, threads: rc.threads };
            let mut op =
                igp::operators::make_cpu_backend(kind, &ds, rc.probes, rc.rff, topts, rc.shards)?;
            if Precision::parse(&rc.precision)?.is_f32() {
                op.set_precision(Precision::F32)?;
            }
            (op, None)
        }
    };
    igp::info!("backend: {}", backend.name());
    let opts = trainer_options(&rc, block)?;
    let mut trainer = Trainer::new(opts, op, &ds);
    let armed = arm_chaos(&mut trainer, &rc)?;
    let out = trainer.run(rc.outer_steps)?;

    println!(
        "dataset={} solver={} estimator={} warm={} backend={} steps={}",
        rc.dataset, rc.solver, rc.estimator, rc.warm_start, rc.backend, rc.outer_steps
    );
    println!(
        "total {:.2}s (solver {:.2}s, {:.1} epochs) final rmse={:.4} llh={:.4}",
        out.total_secs,
        out.solver_secs,
        out.total_epochs,
        out.final_metrics.rmse,
        out.final_metrics.llh
    );
    if armed {
        println!("recovery: {}", out.recovery.summary());
    }

    if let Some(path) = p.get("out") {
        let mut w = igp::util::csv::CsvWriter::create(
            path,
            &["step", "ry", "rz", "iterations", "epochs", "solver_secs", "rmse", "llh"],
        )?;
        for t in &out.telemetry {
            let (rmse, llh) = t
                .metrics
                .map(|m| (m.rmse.to_string(), m.llh.to_string()))
                .unwrap_or(("".into(), "".into()));
            w.row(&[
                t.step.to_string(),
                t.ry.to_string(),
                t.rz.to_string(),
                t.iterations.to_string(),
                t.epochs.to_string(),
                t.solver_secs.to_string(),
                rmse,
                llh,
            ])?;
        }
        w.flush()?;
        igp::info!("telemetry written to {path}");
    }
    Ok(())
}

/// Build and train one CPU-backend trainer from a run config (the serve
/// paths construct several of these for a fleet — same recipe, different
/// seed, so tenants are genuinely different models of the same dataset).
fn build_cpu_trainer(rc: &RunConfig, ds: &igp::data::Dataset, seed: u64) -> Result<Trainer> {
    let backend = BackendKind::parse(&rc.backend)?;
    let topts = TiledOptions { tile: rc.tile, threads: rc.threads };
    let mut op =
        igp::operators::make_cpu_backend(backend, ds, rc.probes, rc.rff, topts, rc.shards)?;
    if Precision::parse(&rc.precision)?.is_f32() {
        op.set_precision(Precision::F32)?;
    }
    let mut opts = trainer_options(rc, None)?;
    opts.seed = seed;
    Ok(Trainer::new(opts, op, ds))
}

/// `igp serve`: train, then answer queries from the amortised pathwise
/// posterior through [`PredictionService`].  `--score in.csv [out.csv]`
/// scores arbitrary query rows (d columns; one optional header line);
/// without it the dataset's held-out split is served and scored, so the
/// command doubles as an end-to-end smoke of the serving path.
/// `--deadline` routes queries through the request queue; `--tenants N`
/// serves a fleet over one shared artifact cache.
fn cmd_serve(args: &[String]) -> Result<()> {
    let mut keys: Vec<&str> = TRAIN_VALUE_KEYS.to_vec();
    keys.extend(["batch", "score", "policy", "queue-cap", "deadline", "tenants"]);
    let p = cli::Parser::new(args, &keys)?;
    let mut rc = run_config_from_args(&p)?;
    if let Some(v) = p.get("policy") {
        rc.serve_policy = v.to_string();
    }
    if let Some(v) = p.get_parsed::<usize>("queue-cap")? {
        rc.serve_queue_cap = v;
    }
    if let Some(v) = p.get_parsed::<u64>("deadline")? {
        rc.serve_deadline = Some(v);
    }
    rc.validate()?;
    anyhow::ensure!(
        rc.backend != "xla",
        "serve needs a query-capable pure-Rust backend (dense|tiled): \
         XLA artifacts fix the prediction shape to the baked-in test split"
    );
    anyhow::ensure!(
        rc.online_chunks <= 1,
        "serve trains on the full dataset; drive online arrivals through the \
         API or examples/serve.rs"
    );
    let batch = p.get_parsed::<usize>("batch")?.unwrap_or(64);
    anyhow::ensure!(batch > 0, "--batch must be positive");
    let tenants = p.get_parsed::<usize>("tenants")?.unwrap_or(1);
    anyhow::ensure!(tenants >= 1, "--tenants must be at least 1");
    let score_in = p.get("score");
    if tenants > 1 {
        anyhow::ensure!(
            score_in.is_none(),
            "--tenants serves the held-out split fleet-wide; --score is single-tenant"
        );
        return cmd_serve_fleet(&rc, tenants, batch);
    }
    // `--score in.csv out.csv` leaves out.csv as a positional; `--out`
    // also works
    let out_path = p.get("out").or_else(|| p.positional.first().map(String::as_str));
    // when predictions stream to stdout, diagnostics must go to stderr or
    // they would corrupt the documented machine-readable mean,var stream
    let csv_to_stdout = score_in.is_some() && out_path.is_none();
    let diag = |msg: String| {
        if csv_to_stdout {
            eprintln!("{msg}");
        } else {
            println!("{msg}");
        }
    };

    let ds = igp::data::generate(&igp::data::spec(&rc.dataset)?);
    igp::info!(
        "backend: {} (serving batch = {batch}, policy = {})",
        rc.backend,
        rc.serve_policy
    );
    let mut trainer = build_cpu_trainer(&rc, &ds, rc.seed)?;
    let armed = arm_chaos(&mut trainer, &rc)?;
    let out = trainer.run(rc.outer_steps)?;
    diag(format!(
        "trained {} steps on {}: rmse={:.4} llh={:.4} ({:.1} epochs, {:.2}s solver)",
        rc.outer_steps,
        rc.dataset,
        out.final_metrics.rmse,
        out.final_metrics.llh,
        out.total_epochs,
        out.solver_secs
    ));

    let mut service = PredictionService::new(
        trainer,
        ServeOptions {
            batch,
            threads: rc.threads,
            policy: StalenessPolicy::parse(&rc.serve_policy)?,
            queue_cap: rc.serve_queue_cap,
        },
    );
    // re-arm through the service so serve-side sites (refresh, cache) are
    // supervised too; train-time recovery counters carry over
    if let Some(spec) = &rc.chaos {
        service.arm_faults(Arc::new(FaultPlan::parse(spec)?));
    }
    // with --deadline the query goes through the request queue (admission
    // cap, EDF drain) instead of the direct path — bitwise-identical
    // answers, but the latency histogram measures enqueue→answer
    let serve_through_queue = |service: &mut PredictionService,
                               x: &igp::linalg::Mat,
                               deadline: Option<u64>|
     -> Result<(Vec<f64>, Vec<f64>)> {
        match deadline {
            None => service.predict(x),
            Some(tick) => {
                service.enqueue_with_deadline(x, Some(tick))?;
                let mut results = service.drain()?;
                anyhow::ensure!(results.len() == 1, "one request in, one result out");
                let r = results.pop().unwrap();
                Ok((r.mean, r.var))
            }
        }
    };
    match score_in {
        Some(input) => {
            let x = igp::util::csv::read_matrix(input)?;
            anyhow::ensure!(
                x.cols == ds.spec.d,
                "{input}: query rows have {} columns but the model has d = {}",
                x.cols,
                ds.spec.d
            );
            let t0 = std::time::Instant::now();
            let (mean, var) = serve_through_queue(&mut service, &x, rc.serve_deadline)?;
            let secs = t0.elapsed().as_secs_f64();
            match out_path {
                Some(path) => {
                    let mut w = igp::util::csv::CsvWriter::create(path, &["mean", "var"])?;
                    for (m, v) in mean.iter().zip(&var) {
                        w.row_display(&[m, v])?;
                    }
                    w.flush()?;
                    diag(format!("scored {} rows -> {path}", x.rows));
                }
                None => {
                    println!("mean,var");
                    for (m, v) in mean.iter().zip(&var) {
                        println!("{m},{v}");
                    }
                }
            }
            diag(format!(
                "served {} rows in {secs:.3}s ({:.0} rows/s)",
                x.rows,
                x.rows as f64 / secs.max(1e-9)
            ));
        }
        None => {
            let t0 = std::time::Instant::now();
            let (mean, var) = serve_through_queue(&mut service, &ds.x_test, rc.serve_deadline)?;
            let m = igp::gp::metrics(&mean, &var, &ds.y_test);
            let secs = t0.elapsed().as_secs_f64();
            diag(format!(
                "test split: rmse={:.4} llh={:.4} ({} rows in {secs:.3}s, {:.0} rows/s)",
                m.rmse,
                m.llh,
                ds.x_test.rows,
                ds.x_test.rows as f64 / secs.max(1e-9)
            ));
        }
    }
    let st = service.stats();
    diag(format!(
        "service: {} rows, {} batches, artifact builds={} hits={} evictions={}",
        st.counters.rows_served,
        st.counters.batches,
        st.counters.artifact_builds,
        st.counters.artifact_hits,
        st.counters.artifact_evictions
    ));
    diag(format!(
        "latency: p50={:.3}ms p99={:.3}ms ({:.0} rows/s in backend eval)",
        st.p50_ns() as f64 * 1e-6,
        st.p99_ns() as f64 * 1e-6,
        st.rows_per_sec()
    ));
    if armed {
        diag(format!("recovery: {}", service.recovery_stats().summary()));
    }
    Ok(())
}

/// `igp serve --tenants N`: a multi-tenant fleet over one shared artifact
/// cache.  Each tenant is the same training recipe at seed, seed+1, ... —
/// genuinely different models — and the held-out split is partitioned
/// across them with staggered deadline ticks (later tenants get earlier
/// deadlines), so the drain demonstrably runs earliest-deadline-first.
fn cmd_serve_fleet(rc: &RunConfig, tenants: usize, batch: usize) -> Result<()> {
    let ds = igp::data::generate(&igp::data::spec(&rc.dataset)?);
    anyhow::ensure!(
        ds.x_test.rows >= tenants,
        "--tenants {tenants} exceeds the {} held-out rows",
        ds.x_test.rows
    );
    let serve_opts = ServeOptions {
        batch,
        threads: rc.threads,
        policy: StalenessPolicy::parse(&rc.serve_policy)?,
        queue_cap: rc.serve_queue_cap,
    };
    // the shared cache holds one artifact per tenant: the point of the
    // fleet is bounded memory, not a cache big enough to never evict
    let mut fleet = ModelFleet::new(tenants);
    for i in 0..tenants {
        let name = format!("tenant{i}");
        let mut trainer = build_cpu_trainer(rc, &ds, rc.seed + i as u64)?;
        arm_chaos(&mut trainer, rc)?;
        let out = trainer.run(rc.outer_steps)?;
        println!(
            "{name}: trained {} steps (seed {}): rmse={:.4} llh={:.4}",
            rc.outer_steps,
            rc.seed + i as u64,
            out.final_metrics.rmse,
            out.final_metrics.llh
        );
        fleet.add_tenant(&name, trainer, serve_opts.clone())?;
    }

    // partition the held-out split across tenants; tenant i's request gets
    // deadline tick (tenants - i), so the LAST-added tenant drains FIRST
    let rows = ds.x_test.rows;
    let mut bounds = Vec::with_capacity(tenants + 1);
    for i in 0..=tenants {
        bounds.push(i * rows / tenants);
    }
    for i in 0..tenants {
        let idx: Vec<usize> = (bounds[i]..bounds[i + 1]).collect();
        let slice = ds.x_test.gather_rows(&idx);
        fleet.enqueue(&format!("tenant{i}"), &slice, Some((tenants - i) as u64))?;
    }
    println!("fleet: {} queued rows across {tenants} tenants", fleet.pending_rows());

    let t0 = std::time::Instant::now();
    let outcome = fleet.drain();
    let secs = t0.elapsed().as_secs_f64();
    for (name, err) in &outcome.refused {
        println!("{name}: refused ({err})");
    }
    println!(
        "drained {} requests in {secs:.3}s, service order: {}",
        outcome.answered.len(),
        outcome
            .answered
            .iter()
            .map(|(n, _)| n.as_str())
            .collect::<Vec<_>>()
            .join(", ")
    );
    for i in 0..tenants {
        let name = format!("tenant{i}");
        let answers: Vec<&igp::serve::RequestResult> = outcome
            .answered
            .iter()
            .filter(|(n, _)| *n == name)
            .map(|(_, r)| r)
            .collect();
        let (mut mean, mut var) = (Vec::new(), Vec::new());
        for r in &answers {
            mean.extend_from_slice(&r.mean);
            var.extend_from_slice(&r.var);
        }
        let y = &ds.y_test[bounds[i]..bounds[i + 1]];
        let m = igp::gp::metrics(&mean, &var, y);
        let st = fleet.stats(&name).expect("tenant exists");
        println!(
            "{name}: {} rows rmse={:.4} llh={:.4} | p50={:.3}ms p99={:.3}ms | \
             builds={} hits={} evictions={}",
            st.counters.rows_served,
            m.rmse,
            m.llh,
            st.p50_ns() as f64 * 1e-6,
            st.p99_ns() as f64 * 1e-6,
            st.counters.artifact_builds,
            st.counters.artifact_hits,
            st.counters.artifact_evictions
        );
    }
    let cache = fleet.cache();
    println!(
        "shared cache: {}/{} entries, builds={} hits={} evictions={}",
        cache.len(),
        cache.capacity(),
        cache.builds(),
        cache.hits(),
        cache.evictions()
    );
    Ok(())
}
