//! [`TiledOperator`]: a matrix-free, cache-blocked, multi-threaded pure-Rust
//! backend for [`KernelOperator`](super::KernelOperator).
//!
//! Unlike [`DenseOperator`](super::DenseOperator), which materialises the
//! full n×n matrix H (O(n²) memory, rebuilt on every `set_hp`), this backend
//! stores only the inputs, hyperparameters and the [`ScaledX`] panel cache
//! — **O(n·d) memory** — and evaluates kernel *panels* of configurable
//! size on the fly inside every product, through the shared Gram-trick
//! engine ([`crate::kernels::panel`]).  Tile loops are distributed over a
//! scoped `std::thread` worker pool (see [`crate::util::parallel`]) with
//! deterministic task assignment.
//!
//! Cost model per call (t = tile size, T = threads, k = s+1):
//! * `hv`      — n² panel entries (d-mult dot + profile each, ~d+6 flops
//!   via the norm cache) + O(n²k/T) apply flops; scratch is one t×t panel
//!   per worker, pooled via [`super::HvScratch`].
//! * `k_cols`/`k_rows` — O(n·b·(d + k) / T), one kernel row per worker.
//! * `grad_quad` — O(n²·(d + k) / T), O(T·d) scratch (scalar-path d-loop:
//!   the lengthscale gradient needs per-dimension differences, which the
//!   Gram trick does not expose).
//! * `rff_eval`/`predict` — row-parallel, O(n·m·d / T).
//!
//! `set_hp` is O(n·d) when the lengthscales change (ScaledX rebuild) and
//! O(1) otherwise — both negligible against any O(n²) product at large n.
//!
//! Determinism/parity contract: output rows are produced by disjoint
//! workers, each accumulating over j in ascending order with exactly
//! `Mat::matmul`'s association over exactly the dense backend's panel
//! values — so `hv`, `k_cols`, `k_rows` and `predict_at` are
//! **bitwise-identical** to `DenseOperator` for every tile size and
//! thread count (enforced by `tests/panel_parity.rs`).

use crate::data::Dataset;
use crate::kernels::panel::{self, ScaledX};
use crate::kernels::{self, Hyperparams, KernelFamily};
use crate::linalg::{micro, Mat};
use crate::util::parallel::{num_threads, parallel_reduce, parallel_row_blocks};
use crate::util::stats;

use super::{dl_weight, rff_fill_row, HvScratch, KernelOperator, Precision};

/// Tuning knobs for the tiled backend.
#[derive(Clone, Debug)]
pub struct TiledOptions {
    /// Tile edge length (rows/cols of one on-the-fly kernel block).
    /// 256 keeps a f64 tile (512 KB) inside typical L2 caches.
    pub tile: usize,
    /// Worker threads; 0 = auto (`IGP_THREADS` env var, else all cores).
    pub threads: usize,
}

impl Default for TiledOptions {
    fn default() -> Self {
        TiledOptions { tile: 256, threads: 0 }
    }
}

/// Matrix-free multi-threaded kernel operator (O(n·d) memory).
pub struct TiledOperator {
    x: Mat,
    x_test: Mat,
    s: usize,
    m: usize,
    family: KernelFamily,
    hp: Hyperparams,
    scaled: ScaledX,
    tile: usize,
    threads: usize,
    precision: Precision,
}

impl TiledOperator {
    /// Build with default tile/thread options.
    pub fn new(ds: &Dataset, s: usize, m: usize) -> Self {
        Self::with_options(ds, s, m, TiledOptions::default())
    }

    pub fn with_options(ds: &Dataset, s: usize, m: usize, opts: TiledOptions) -> Self {
        let hp = Hyperparams::ones(ds.spec.d);
        let scaled = ScaledX::new(&ds.x_train, &hp.ell);
        TiledOperator {
            x: ds.x_train.clone(),
            x_test: ds.x_test.clone(),
            s,
            m,
            family: ds.spec.family,
            hp,
            scaled,
            tile: opts.tile.max(1),
            threads: num_threads(if opts.threads == 0 { None } else { Some(opts.threads) }),
            precision: Precision::F64,
        }
    }

    pub fn tile_size(&self) -> usize {
        self.tile
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Number of row/col tiles covering n points.
    fn ntiles(&self) -> usize {
        let n = self.x.rows;
        (n + self.tile - 1) / self.tile
    }

    /// Row range of tile `b`.
    fn tile_range(&self, b: usize) -> (usize, usize) {
        let n = self.x.rows;
        (b * self.tile, ((b + 1) * self.tile).min(n))
    }

    fn sf2(&self) -> f64 {
        self.hp.sigf * self.hp.sigf
    }

    /// Shared body of `hv_into`/`hv_into_prec`: identical tiling, worker
    /// schedule and apply order at both precisions — only the panel fill
    /// dispatches on `prec`, so the F64 instantiation is the pre-existing
    /// bitwise-reference path.
    fn hv_into_impl(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch, prec: Precision) {
        let n = self.n();
        assert_eq!(v.rows, n);
        let k = v.cols;
        assert_eq!(
            (out.rows, out.cols),
            (n, k),
            "hv_into: output is {}x{} but the product is {}x{}",
            out.rows,
            out.cols,
            n,
            k
        );
        let noise_var = self.hp.noise_var();
        let sf2 = self.sf2();
        let tile = self.tile;
        parallel_row_blocks(&mut out.data, k, tile, self.threads, |r0, rows, block| {
            block.fill(0.0);
            let mut pbuf = scratch.take(rows * tile);
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + tile).min(n);
                let w = j1 - j0;
                let panel = &mut pbuf[..rows * w];
                panel::fill_panel_prec(
                    &self.scaled,
                    r0,
                    r0 + rows,
                    &self.scaled,
                    j0,
                    j1,
                    sf2,
                    self.family,
                    panel,
                    prec,
                );
                // sigma^2 I where the panel crosses the global diagonal —
                // the same `k_ii + noise_var` the dense add_diag produces
                let (d0, d1) = (r0.max(j0), (r0 + rows).min(j1));
                for i in d0..d1 {
                    panel[(i - r0) * w + (i - j0)] += noise_var;
                }
                panel::apply_panel(panel, rows, w, j0, v, block);
                j0 = j1;
            }
            scratch.put(pbuf);
        });
    }

    fn k_cols_impl(&self, idx: &[usize], u: &Mat, prec: Precision) -> Mat {
        assert_eq!(u.rows, idx.len());
        let n = self.n();
        let nb = idx.len();
        let k = u.cols;
        let sb = self.scaled.gather(idx);
        let sf2 = self.sf2();
        let mut out = Mat::zeros(n, k);
        parallel_row_blocks(&mut out.data, k, self.tile, self.threads, |r0, rows, block| {
            let mut krow = vec![0.0; nb];
            for r in 0..rows {
                let i = r0 + r;
                panel::fill_row_prec(&self.scaled, i, &sb, 0, sf2, self.family, &mut krow, prec);
                panel::apply_panel(&krow, 1, nb, 0, u, &mut block[r * k..(r + 1) * k]);
            }
        });
        out
    }

    fn k_rows_impl(&self, idx: &[usize], v: &Mat, prec: Precision) -> Mat {
        let n = self.n();
        assert_eq!(v.rows, n);
        let k = v.cols;
        let sa = self.scaled.gather(idx);
        let sf2 = self.sf2();
        let mut out = Mat::zeros(idx.len(), k);
        let rows_total = idx.len().max(1);
        let block = (rows_total + self.threads - 1) / self.threads;
        parallel_row_blocks(&mut out.data, k, block, self.threads, |r0, rows, blk| {
            let mut krow = vec![0.0; n];
            for r in 0..rows {
                panel::fill_row_prec(&sa, r0 + r, &self.scaled, 0, sf2, self.family, &mut krow, prec);
                panel::apply_panel(&krow, 1, n, 0, v, &mut blk[r * k..(r + 1) * k]);
            }
        });
        out
    }

    fn predict_at_impl(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
        prec: Precision,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        let n = self.n();
        let d = self.d();
        anyhow::ensure!(
            x_query.cols == d,
            "predict_at: query has d = {} but the model has d = {}",
            x_query.cols,
            d
        );
        let tq = x_query.rows;
        assert_eq!(vy.len(), n);
        assert_eq!(zhat.rows, n);
        assert_eq!(omega0.rows, d);
        let m = omega0.cols;
        assert_eq!(wts.rows, 2 * m);
        let s = wts.cols;
        assert_eq!(zhat.cols, s);
        let amp = self.hp.sigf * (1.0 / m as f64).sqrt();
        let mut qs = ScaledX::new(x_query, &self.hp.ell);
        if prec.is_f32() {
            qs.ensure_f32();
        }
        let sf2 = self.sf2();
        // packed output: column 0 = mean, columns 1..=s = samples
        let width = 1 + s;
        let mut packed = Mat::zeros(tq, width);
        parallel_row_blocks(
            &mut packed.data,
            width,
            self.tile,
            self.threads,
            |r0, rows, block| {
                let mut krow = vec![0.0; n];
                let mut phi = vec![0.0; 2 * m];
                let mut corr = vec![0.0; s];
                for r in 0..rows {
                    let i = r0 + r;
                    panel::fill_row_prec(&qs, i, &self.scaled, 0, sf2, self.family, &mut krow, prec);
                    let orow = &mut block[r * width..(r + 1) * width];
                    orow[0] = stats::dot(&krow, vy);
                    rff_fill_row(qs.row(i), omega0, amp, &mut phi);
                    let srow = &mut orow[1..];
                    for (c, &pc) in phi.iter().enumerate() {
                        if pc == 0.0 {
                            continue;
                        }
                        micro::axpy(srow, pc, wts.row(c));
                    }
                    // + K(Xq, X) (vy - zhat): accumulated apart, added once
                    for v in corr.iter_mut() {
                        *v = 0.0;
                    }
                    for j in 0..n {
                        let kj = krow[j];
                        if kj == 0.0 {
                            continue;
                        }
                        let zr = zhat.row(j);
                        for q in 0..s {
                            corr[q] += kj * (vy[j] - zr[q]);
                        }
                    }
                    for q in 0..s {
                        srow[q] += corr[q];
                    }
                }
            },
        );
        let mut mean = Vec::with_capacity(tq);
        let mut samples = Mat::zeros(tq, s);
        for i in 0..tq {
            let prow = packed.row(i);
            mean.push(prow[0]);
            samples.row_mut(i).copy_from_slice(&prow[1..]);
        }
        Ok((mean, samples))
    }
}

impl KernelOperator for TiledOperator {
    fn n(&self) -> usize {
        self.x.rows
    }
    fn d(&self) -> usize {
        self.x.cols
    }
    fn s(&self) -> usize {
        self.s
    }
    fn m(&self) -> usize {
        self.m
    }
    fn family(&self) -> KernelFamily {
        self.family
    }
    fn x(&self) -> &Mat {
        &self.x
    }
    fn x_test(&self) -> &Mat {
        &self.x_test
    }
    fn hp(&self) -> &Hyperparams {
        &self.hp
    }

    fn set_hp(&mut self, hp: &Hyperparams) {
        assert_eq!(hp.ell.len(), self.d());
        self.hp = hp.clone();
        // rebuilds only when the lengthscale bits changed (O(n·d));
        // sigf/sigma-only steps keep the cache
        self.scaled.refresh(&self.x, &hp.ell);
        if self.precision.is_f32() {
            // refresh carries an existing mirror across rebuilds; this is
            // a no-op belt for the never-built case
            self.scaled.ensure_f32();
        }
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn set_precision(&mut self, prec: Precision) -> anyhow::Result<()> {
        self.precision = prec;
        if prec.is_f32() {
            self.scaled.ensure_f32();
        }
        Ok(())
    }

    /// Online data arrival: append the new rows to X and grow the panel
    /// cache — O(n_new · d).  The tile grid and the deterministic strided
    /// schedule are derived from `n` on every call, and grown ScaledX rows
    /// are bitwise-identical to a fresh build's, so all products
    /// immediately cover the extended dataset (the online parity tests
    /// check the result against a freshly built operator).
    fn extend(&mut self, x_new: &Mat) -> anyhow::Result<()> {
        anyhow::ensure!(x_new.rows > 0, "extend: empty chunk");
        anyhow::ensure!(
            x_new.cols == self.x.cols,
            "extend: chunk has d = {} but the operator holds d = {}",
            x_new.cols,
            self.x.cols
        );
        self.x.append_rows(x_new);
        self.scaled.extend(x_new, &self.hp.ell);
        Ok(())
    }

    /// H @ V without materialising H: thin allocating wrapper over
    /// [`TiledOperator::hv_into`] (one fresh output and scratch pool per
    /// call; solver loops use `hv_into` directly and allocate neither).
    fn hv(&self, v: &Mat) -> Mat {
        let mut out = Mat::zeros(self.n(), v.cols);
        self.hv_into(v, &mut out, &HvScratch::default());
        out
    }

    /// H @ V through the panel engine, row-tile-parallel: each worker owns
    /// a disjoint block of *output rows* and sweeps the column tiles in
    /// ascending j, filling a Gram-trick panel (plus the sigma² I
    /// contribution on the global diagonal) and applying it against all k
    /// RHS columns with `Mat::matmul`'s exact association.
    ///
    /// Because every output row accumulates over j in the same global
    /// order as the dense backend's `H.matmul(v)` row update — over the
    /// same panel values — the result is **bitwise-identical** to dense
    /// for every tile size and thread count.  This also folds away the old
    /// per-call thread-partial [n, k] buffers and their serial reduction:
    /// writes are disjoint, so no reduction exists, and the only scratch
    /// is one tile panel per worker, pooled in `scratch`.
    fn hv_into(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch) {
        self.hv_into_impl(v, out, scratch, Precision::F64);
    }

    fn hv_into_prec(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch, prec: Precision) {
        self.hv_into_impl(v, out, scratch, prec);
    }

    /// K(X, X[idx]) @ U, row-parallel over tiles of X (the sigma^2 scatter
    /// on `idx` rows is applied by the caller, as with the other backends).
    ///
    /// One panel row per output row, applied in ascending-b `matmul` order
    /// over the gathered [`ScaledX`] — bitwise equal to the dense backend's
    /// `cross_matrix(...).matmul(u)` (AP trajectories match dense exactly).
    fn k_cols(&self, idx: &[usize], u: &Mat) -> Mat {
        self.k_cols_impl(idx, u, Precision::F64)
    }

    fn k_cols_prec(&self, idx: &[usize], u: &Mat, prec: Precision) -> Mat {
        self.k_cols_impl(idx, u, prec)
    }

    /// K(X[idx], X) @ V, parallel over the (small) batch rows.
    ///
    /// One full panel row (all n columns) per batch row, applied in
    /// ascending-j `matmul` order — bitwise equal to the dense backend's
    /// `cross_matrix(...).matmul(v)` (SGD trajectories match dense
    /// exactly).
    fn k_rows(&self, idx: &[usize], v: &Mat) -> Mat {
        self.k_rows_impl(idx, v, Precision::F64)
    }

    fn k_rows_prec(&self, idx: &[usize], v: &Mat, prec: Precision) -> Mat {
        self.k_rows_impl(idx, v, prec)
    }

    /// sum_j w_j a_j^T (dH/dtheta) b_j, tiled over (i, j) pairs with the
    /// weighted coefficient C_ij = sum_q w_q a_iq b_jq formed on the fly —
    /// O(1) extra memory per worker instead of DenseOperator's O(n²) C.
    fn grad_quad(&self, a: &Mat, b: &Mat, w: &[f64]) -> Vec<f64> {
        let (n, d) = (self.n(), self.d());
        assert_eq!(a.rows, n);
        assert_eq!(b.rows, n);
        assert_eq!(a.cols, b.cols);
        assert_eq!(w.len(), a.cols);
        let k = a.cols;
        // aw = a * diag(w), precomputed once (O(n k))
        let aw = super::weighted_cols(a, w);
        let nb = self.ntiles();
        let sf2 = self.hp.sigf * self.hp.sigf;
        let partials = parallel_reduce(
            nb * nb,
            self.threads,
            || vec![0.0; d + 2],
            |grad, p| {
                let (bi, bj) = (p / nb, p % nb);
                let (i0, i1) = self.tile_range(bi);
                let (j0, j1) = self.tile_range(bj);
                for i in i0..i1 {
                    let awi = &aw.data[i * k..(i + 1) * k];
                    let xi = self.x.row(i);
                    for j in j0..j1 {
                        let bj_row = &b.data[j * k..(j + 1) * k];
                        let cij = stats::dot(awi, bj_row);
                        if cij == 0.0 {
                            continue;
                        }
                        let xj = self.x.row(j);
                        let sq = kernels::sqdist_scaled(xi, xj, &self.hp.ell);
                        let h_r = dl_weight(sq, self.family);
                        for kk in 0..d {
                            let dlt = (xi[kk] - xj[kk]) / self.hp.ell[kk];
                            grad[kk] += cij * sf2 * h_r * dlt * dlt / self.hp.ell[kk];
                        }
                        grad[d] += cij * 2.0 * sf2 * self.family.unit_cov(sq) / self.hp.sigf;
                    }
                }
            },
        );
        let mut grad = vec![0.0; d + 2];
        for p in &partials {
            for (g, v) in grad.iter_mut().zip(p) {
                *g += v;
            }
        }
        // noise component: shared single-source formula with the dense path
        grad[d + 1] = super::noise_grad(a, b, w, self.hp.sigma);
        grad
    }

    /// Xi = Phi(X) wts + sigma * noise, row-parallel with a per-worker
    /// feature-row scratch (never materialises the full [n, 2m] Phi).
    fn rff_eval(&self, omega0: &Mat, wts: &Mat, noise: &Mat) -> Mat {
        let n = self.n();
        let d = self.d();
        assert_eq!(omega0.rows, d);
        let m = omega0.cols;
        assert_eq!(wts.rows, 2 * m);
        let s = wts.cols;
        assert_eq!((noise.rows, noise.cols), (n, s));
        let amp = self.hp.sigf * (1.0 / m as f64).sqrt();
        let sigma = self.hp.sigma;
        let mut out = Mat::zeros(n, s);
        parallel_row_blocks(&mut out.data, s, self.tile, self.threads, |r0, rows, block| {
            let mut phi = vec![0.0; 2 * m];
            for r in 0..rows {
                let i = r0 + r;
                rff_fill_row(self.scaled.row(i), omega0, amp, &mut phi);
                let orow = &mut block[r * s..(r + 1) * s];
                for (c, &pc) in phi.iter().enumerate() {
                    if pc == 0.0 {
                        continue;
                    }
                    micro::axpy(orow, pc, wts.row(c));
                }
                let nrow = noise.row(i);
                for q in 0..s {
                    orow[q] += sigma * nrow[q];
                }
            }
        });
        out
    }

    /// Pathwise-conditioned predictions at arbitrary query inputs,
    /// row-parallel with per-worker K(x_q, X) row and Phi(x_q) scratch
    /// buffers — query blocks stream against the training rows in
    /// O(b·n·d) without ever materialising K(X*, X).
    ///
    /// Kernel rows come from the same panel fills as the dense backend's
    /// `cross_matrix` over an identically built query [`ScaledX`], and the
    /// accumulation order deliberately mirrors the dense path
    /// ([`super::rff_fill_row`] for features, `Mat::matmul`'s k-major
    /// order for the feature product, and the K(Xq, X)(vy - zhat)
    /// correction summed into a separate buffer before one final add, like
    /// dense's `matmul` + `add_assign`): the serve parity suite demands
    /// tiled == dense **bitwise** at arbitrary query points.
    fn predict_at(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.predict_at_impl(x_query, vy, zhat, omega0, wts, Precision::F64)
    }

    fn predict_at_prec(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
        prec: Precision,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.predict_at_impl(x_query, vy, zhat, omega0, wts, prec)
    }

    /// The tiled backend's `predict_at` already parallelises over query
    /// rows on its own worker pool (`parallel_row_blocks` in `tile`-row
    /// blocks), so the generic block fan-out would only nest thread pools
    /// and copy each block.  Results are per-row independent, so
    /// forwarding the whole query produces identical bits — and counts as
    /// ONE executed evaluation block, which is what the serving stats
    /// report.
    fn predict_batched(
        &self,
        x_query: &Mat,
        _batch: usize,
        _threads: usize,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat, u64)> {
        let blocks = if x_query.rows == 0 { 0 } else { 1 };
        let (mean, samples) = self.predict_at(x_query, vy, zhat, omega0, wts)?;
        Ok((mean, samples, blocks))
    }

    /// Exact MLL via the O(n³) Cholesky baseline — only sane at small n,
    /// exactly like `DenseOperator` (callers gate via `track_exact`).
    fn exact_mll(&self, y: &[f64]) -> Option<(f64, Vec<f64>)> {
        let gp = crate::gp::ExactGp::fit(&self.x, y, &self.hp, self.family).ok()?;
        Some((gp.mll(y), gp.mll_grad()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::operators::DenseOperator;
    use crate::util::rng::Rng;

    fn ops(tile: usize, threads: usize) -> (TiledOperator, DenseOperator) {
        let ds = data::generate(&data::spec("test").unwrap());
        let hp = Hyperparams { ell: vec![0.9, 1.2, 0.7, 1.1], sigf: 1.2, sigma: 0.35 };
        let mut tiled =
            TiledOperator::with_options(&ds, 4, 16, TiledOptions { tile, threads });
        tiled.set_hp(&hp);
        let mut dense = DenseOperator::new(&ds, 4, 16);
        dense.set_hp(&hp);
        (tiled, dense)
    }

    #[test]
    fn hv_matches_dense_bitwise_across_tiles_and_threads() {
        // the panel engine gives both backends the same kernel values and
        // the same accumulation order, so parity is exact — not tolerance
        for (tile, threads) in [(1, 1), (7, 2), (64, 3), (256, 4), (1000, 2)] {
            let (tiled, dense) = ops(tile, threads);
            let mut rng = Rng::new(0);
            let v = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| rng.gaussian());
            let a = tiled.hv(&v);
            let b = dense.hv(&v);
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "tile={tile} threads={threads} elem {i}: {x} vs {y}"
                );
            }
            // hv_into with a reused dirty buffer and shared scratch keeps
            // the bits
            let scratch = HvScratch::default();
            let mut out = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| -3.25);
            tiled.hv_into(&v, &mut out, &scratch);
            assert_eq!(out.data, a.data);
            tiled.hv_into(&v, &mut out, &scratch);
            assert_eq!(out.data, a.data);
        }
    }

    #[test]
    fn hv_is_deterministic() {
        let (tiled, _) = ops(33, 4);
        let mut rng = Rng::new(1);
        let v = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| rng.gaussian());
        let a = tiled.hv(&v);
        let b = tiled.hv(&v);
        assert_eq!(a, b, "hv must be bit-deterministic for a fixed thread count");
    }

    #[test]
    fn set_hp_is_matrix_free() {
        // set_hp must not allocate O(n^2): just verify repeated set_hp with
        // alternating hp changes hv output accordingly.
        let (mut tiled, mut dense) = ops(64, 2);
        let mut rng = Rng::new(2);
        let v = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| rng.gaussian());
        for sigma in [0.1, 0.5, 0.9] {
            let hp = Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma };
            tiled.set_hp(&hp);
            dense.set_hp(&hp);
            assert!(tiled.hv(&v).max_abs_diff(&dense.hv(&v)) < 1e-10);
        }
    }

    #[test]
    fn k_cols_and_rows_match_dense() {
        let (tiled, dense) = ops(50, 3);
        let mut rng = Rng::new(3);
        let idx = rng.sample_indices(tiled.n(), 32);
        let u = Mat::from_fn(idx.len(), tiled.k_width(), |_, _| rng.gaussian());
        let err = tiled.k_cols(&idx, &u).max_abs_diff(&dense.k_cols(&idx, &u));
        assert!(err < 1e-10, "k_cols err {err}");
        let v = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| rng.gaussian());
        let err = tiled.k_rows(&idx, &v).max_abs_diff(&dense.k_rows(&idx, &v));
        assert!(err < 1e-10, "k_rows err {err}");
    }

    #[test]
    fn grad_quad_matches_dense() {
        let (tiled, dense) = ops(48, 4);
        let mut rng = Rng::new(4);
        let k = tiled.k_width();
        let a = Mat::from_fn(tiled.n(), k, |_, _| rng.gaussian());
        let b = Mat::from_fn(tiled.n(), k, |_, _| rng.gaussian());
        let mut w = vec![-0.125; k];
        w[0] = 0.5;
        let g1 = tiled.grad_quad(&a, &b, &w);
        let g2 = dense.grad_quad(&a, &b, &w);
        for (i, (x, y)) in g1.iter().zip(&g2).enumerate() {
            assert!(
                (x - y).abs() < 1e-10 * (1.0 + y.abs()),
                "comp {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn rff_and_predict_match_dense() {
        let (tiled, dense) = ops(40, 2);
        let mut rng = Rng::new(5);
        let (d, m, s, n) = (tiled.d(), 8, 3, tiled.n());
        let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let noise = Mat::from_fn(n, s, |_, _| rng.gaussian());
        let e = tiled
            .rff_eval(&omega0, &wts, &noise)
            .max_abs_diff(&dense.rff_eval(&omega0, &wts, &noise));
        assert!(e < 1e-12, "rff_eval err {e}");

        let vy = rng.gaussian_vec(n);
        let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
        let (m1, s1) = tiled.predict(&vy, &zhat, &omega0, &wts);
        let (m2, s2) = dense.predict(&vy, &zhat, &omega0, &wts);
        for (a, b) in m1.iter().zip(&m2) {
            assert!((a - b).abs() < 1e-10);
        }
        assert!(s1.max_abs_diff(&s2) < 1e-10, "{}", s1.max_abs_diff(&s2));
    }

    #[test]
    fn predict_at_is_bitwise_equal_to_dense() {
        // the serving contract is stronger than the tolerance-based parity
        // of the training-path products: at arbitrary query points, tiled
        // and dense must agree in every bit, for any tile size and thread
        // count, whole-query or batched
        let mut rng = Rng::new(11);
        for (tile, threads) in [(1, 1), (7, 2), (64, 3), (300, 4)] {
            let (tiled, dense) = ops(tile, threads);
            let (d, m, s, n) = (tiled.d(), 8, 3, tiled.n());
            let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
            let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
            let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
            let vy = rng.gaussian_vec(n);
            let xq = Mat::from_fn(29, d, |_, _| rng.gaussian());
            let (m1, s1) = tiled.predict_at(&xq, &vy, &zhat, &omega0, &wts).unwrap();
            let (m2, s2) = dense.predict_at(&xq, &vy, &zhat, &omega0, &wts).unwrap();
            for (i, (a, b)) in m1.iter().zip(&m2).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "tile={tile} mean row {i}: {a} vs {b}");
            }
            for (i, (a, b)) in s1.data.iter().zip(&s2.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "tile={tile} sample {i}: {a} vs {b}");
            }
            // batched sweep keeps the bits too, coalesced into ONE block
            let (mb, sb, blocks) =
                tiled.predict_batched(&xq, 8, threads, &vy, &zhat, &omega0, &wts).unwrap();
            assert_eq!(blocks, 1, "tiled coalesces the query into one executed block");
            assert!(m1.iter().zip(&mb).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert!(s1.data.iter().zip(&sb.data).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn extended_tiled_matches_extended_dense() {
        // grow both backends with the same chunk; hv must still agree
        // (the extension keeps the strided schedule derived from n)
        let (mut tiled, mut dense) = ops(48, 3);
        let mut rng = Rng::new(7);
        let chunk = Mat::from_fn(37, tiled.d(), |_, _| rng.gaussian());
        tiled.extend(&chunk).unwrap();
        dense.extend(&chunk).unwrap();
        assert_eq!(tiled.n(), dense.n());
        let v = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| rng.gaussian());
        let err = tiled.hv(&v).max_abs_diff(&dense.hv(&v));
        assert!(err < 1e-10, "post-extend hv err {err}");
        // determinism must survive the re-tile
        assert_eq!(tiled.hv(&v), tiled.hv(&v));
    }

    #[test]
    fn exact_mll_matches_dense() {
        let (tiled, dense) = ops(64, 2);
        let ds = data::generate(&data::spec("test").unwrap());
        let (l1, g1) = tiled.exact_mll(&ds.y_train).unwrap();
        let (l2, g2) = dense.exact_mll(&ds.y_train).unwrap();
        assert!((l1 - l2).abs() < 1e-9);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
