//! The boundary between the Rust coordinator (L3) and the compute backends:
//! every O(n^2) product the solvers and estimators need is behind
//! [`KernelOperator`].
//!
//! Three implementations (select with [`BackendKind`] / `--backend`):
//!
//! * [`DenseOperator`] — pure Rust, materialises the full n×n matrix H
//!   (O(n²) memory, rebuilt on every `set_hp`).  The test oracle and the
//!   backend for tiny problems.  Lives here.
//! * [`TiledOperator`] — pure Rust, **matrix-free**: kernel tiles are
//!   evaluated on the fly (O(n·d) memory) and tile loops run on a
//!   multi-threaded worker pool.  The CPU path for n where dense storage
//!   is impossible.  Lives in `tiled`.
//! * [`XlaOperator`] — executes AOT Pallas artifacts through PJRT; the
//!   accelerator path.  Lives in `runtime::xla_op`, re-exported here, and
//!   requires the `xla` cargo feature plus compiled artifacts.
//!
//! A fourth layout, [`ShardedOperator`] (`--shards S`, lives in `sharded`),
//! partitions the tiled backend's rows into S shards with per-shard panel
//! caches — bitwise-identical products, per-shard memory scaling, and a
//! partial-buffer communication contract for future multi-process runs.
//!
//! Memory/knob summary:
//!
//! | backend | memory   | `set_hp` | parallelism        | knobs              |
//! |---------|----------|----------|--------------------|--------------------|
//! | dense   | O(n²)    | O(n²)    | single-threaded    | —                  |
//! | tiled   | O(n·d)   | O(n·d)¹  | `threads` workers  | `tile`, `threads`  |
//! | xla     | device   | O(1)     | XLA-managed        | artifact shapes    |
//!
//! ¹ only when the lengthscales change (the [`ScaledX`] panel cache is
//!   rebuilt); sigf/sigma-only steps are O(1).  Per-call scratch is one
//!   tile panel per worker, pooled through [`HvScratch`].
//!
//! Every pairwise kernel evaluation in both pure-Rust backends goes
//! through the shared panel engine ([`crate::kernels::panel`]): same fill
//! functions, same accumulation order, so tiled == dense is **bitwise**
//! on `hv`, `k_cols`, `k_rows` and `predict_at` by construction.

pub mod sharded;
pub mod tiled;

use crate::data::Dataset;
use crate::kernels::panel::{self, ScaledX};
use crate::kernels::{self, Hyperparams, KernelFamily};
use crate::linalg::Mat;

pub use crate::kernels::panel::Precision;
pub use crate::runtime::xla_op::XlaOperator;
pub use sharded::ShardedOperator;
pub use tiled::{TiledOperator, TiledOptions};

/// Which [`KernelOperator`] implementation to run against.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Dense,
    Tiled,
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "dense" => BackendKind::Dense,
            "tiled" => BackendKind::Tiled,
            "xla" => BackendKind::Xla,
            other => anyhow::bail!("unknown backend '{other}' (dense|tiled|xla)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Dense => "dense",
            BackendKind::Tiled => "tiled",
            BackendKind::Xla => "xla",
        }
    }
}

/// Construct a pure-Rust backend for a dataset (`Dense` or `Tiled`; the
/// `Xla` backend needs a compiled [`crate::runtime::Model`] and is built by
/// the caller).  `s` = probe count, `m` = RFF feature pairs.  `shards > 1`
/// selects the sharded tiled layout ([`ShardedOperator`]) — bitwise-equal
/// products, per-shard panel caches; only the tiled backend shards.
pub fn make_cpu_backend(
    kind: BackendKind,
    ds: &Dataset,
    s: usize,
    m: usize,
    opts: TiledOptions,
    shards: usize,
) -> anyhow::Result<Box<dyn KernelOperator>> {
    if shards > 1 && kind != BackendKind::Tiled {
        anyhow::bail!(
            "--shards {} requires the tiled backend (got '{}')",
            shards,
            kind.name()
        );
    }
    Ok(match kind {
        BackendKind::Dense => Box::new(DenseOperator::new(ds, s, m)),
        BackendKind::Tiled if shards > 1 => {
            Box::new(ShardedOperator::with_options(ds, s, m, opts, shards))
        }
        BackendKind::Tiled => Box::new(TiledOperator::with_options(ds, s, m, opts)),
        BackendKind::Xla => anyhow::bail!(
            "backend 'xla' needs compiled artifacts; construct XlaOperator from a runtime Model"
        ),
    })
}

/// Everything L3 needs from the model, independent of backend.
///
/// Width contract: `hv`, `k_cols`, `k_rows` operate on the solver batch of
/// `k_width() = s + 1` columns `[y | probes]`; `grad_quad` likewise takes
/// s+1 column pairs.  The XLA backend compiled these shapes statically.
pub trait KernelOperator {
    fn n(&self) -> usize;
    fn d(&self) -> usize;
    /// Number of probe vectors s (batch width is s + 1).
    fn s(&self) -> usize;
    /// RFF sin/cos pairs available to the pathwise estimator.
    fn m(&self) -> usize;
    fn family(&self) -> KernelFamily;
    fn x(&self) -> &Mat;
    fn x_test(&self) -> &Mat;

    fn hp(&self) -> &Hyperparams;
    /// Update hyperparameters (invalidates any cached factorisations).
    fn set_hp(&mut self, hp: &Hyperparams);

    /// Compute precision this backend has been switched to.  `F64` (the
    /// default, and the only mode most backends support) is the bitwise
    /// reference; `F32` means the backend holds f32 mirrors and the
    /// `*_prec` product variants below may run reduced-precision panels.
    fn precision(&self) -> Precision {
        Precision::F64
    }

    /// Switch the backend's compute precision.  `F32` builds the f32
    /// mirrors of the panel caches (lazily, O(n·d)); backends without a
    /// reduced-precision path reject it.  Switching never perturbs the
    /// f64 products — `hv`/`k_cols`/… stay the bitwise reference at any
    /// setting; only the `*_prec` variants consult the mode.
    fn set_precision(&mut self, prec: Precision) -> anyhow::Result<()> {
        match prec {
            Precision::F64 => Ok(()),
            Precision::F32 => {
                anyhow::bail!("this backend does not support f32 compute")
            }
        }
    }

    fn k_width(&self) -> usize {
        self.s() + 1
    }

    /// H @ V for the full batch V [n, s+1].
    fn hv(&self, v: &Mat) -> Mat;

    /// H @ V into a caller-owned output with reusable scratch — the
    /// allocation-free form of [`KernelOperator::hv`] for solver inner
    /// loops (`hv` stays as a thin allocating wrapper).  `out` must be
    /// [n, v.cols] and is fully overwritten; `scratch` pools per-worker
    /// panel buffers across calls.
    ///
    /// Contract: bitwise-identical to `hv` for every (out, scratch) reuse
    /// pattern.  The default clones through `hv` for backends without a
    /// buffer-reusing path (XLA).
    fn hv_into(&self, v: &Mat, out: &mut Mat, _scratch: &HvScratch) {
        *out = self.hv(v);
    }

    /// [`KernelOperator::hv_into`] at an explicit compute precision.  The
    /// `F64` arm is *the same code path* as `hv_into` (bitwise-identical);
    /// the `F32` arm is only meaningful after `set_precision(F32)` and
    /// runs reduced-precision panel products with f64 accumulation in the
    /// identical block order.  The default ignores the mode and stays on
    /// the f64 path, which is correct for backends without f32 support.
    fn hv_into_prec(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch, _prec: Precision) {
        self.hv_into(v, out, scratch);
    }

    /// K(X, X[idx]) @ U with U [idx.len(), s+1]  (AP column update; the
    /// sigma^2 part of H[:, idx] is applied by the caller as a scatter).
    fn k_cols(&self, idx: &[usize], u: &Mat) -> Mat;

    /// [`KernelOperator::k_cols`] at an explicit compute precision (same
    /// contract as [`KernelOperator::hv_into_prec`]).
    fn k_cols_prec(&self, idx: &[usize], u: &Mat, _prec: Precision) -> Mat {
        self.k_cols(idx, u)
    }

    /// K(X[idx], X) @ V with V [n, s+1]  (SGD row batch).
    fn k_rows(&self, idx: &[usize], v: &Mat) -> Mat;

    /// [`KernelOperator::k_rows`] at an explicit compute precision (same
    /// contract as [`KernelOperator::hv_into_prec`]).
    fn k_rows_prec(&self, idx: &[usize], v: &Mat, _prec: Precision) -> Mat {
        self.k_rows(idx, v)
    }

    /// All d+2 components of  sum_j w_j a_j^T (dH/dtheta) b_j.
    fn grad_quad(&self, a: &Mat, b: &Mat, w: &[f64]) -> Vec<f64>;

    /// Append newly arrived training inputs (online data-arrival mode):
    /// after the call, `n()` has grown by `x_new.rows` and every product
    /// covers the extended dataset under the *current* hyperparameters.
    ///
    /// Contract (enforced by the online parity tests): the extended
    /// operator must be indistinguishable from one freshly built on the
    /// concatenated data — bitwise for the pure-Rust backends.
    ///
    /// Backends with static shapes (compiled XLA artifacts) cannot grow
    /// and return an error; the coordinator surfaces it to the caller.
    fn extend(&mut self, _x_new: &Mat) -> anyhow::Result<()> {
        anyhow::bail!("this backend has static shapes and does not support online data arrival")
    }

    /// Pathwise probe targets Xi = Phi(X) wts + sigma * noise  [n, s].
    fn rff_eval(&self, omega0: &Mat, wts: &Mat, noise: &Mat) -> Mat;

    /// Pathwise-conditioned predictions at *arbitrary* query inputs
    /// `x_query` [b, d]: (mean [b], samples [b, s]).  This is the serving
    /// primitive — unlike [`KernelOperator::predict`], it is not tied to
    /// the dataset's baked-in test split.
    ///
    /// Contract (enforced by `tests/serve_parity.rs`): results are
    /// *per-row independent* — predicting a query set in one call, or
    /// split into arbitrary row batches, or under any thread count, gives
    /// bitwise-identical values — and the tiled and dense backends agree
    /// bitwise (both mirror `Mat::matmul`'s accumulation order; see the
    /// note on [`Mat::matmul`]).
    ///
    /// Backends with static shapes (compiled XLA artifacts) cannot take
    /// arbitrary query matrices and return an error.
    fn predict_at(
        &self,
        _x_query: &Mat,
        _vy: &[f64],
        _zhat: &Mat,
        _omega0: &Mat,
        _wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        anyhow::bail!(
            "this backend has static shapes and cannot evaluate arbitrary query points"
        )
    }

    /// [`KernelOperator::predict_at`] at an explicit compute precision
    /// (same contract as [`KernelOperator::hv_into_prec`]; the serving
    /// layer may trade cross-covariance precision for throughput while
    /// keeping the f64 path for comparison).
    fn predict_at_prec(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
        _prec: Precision,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.predict_at(x_query, vy, zhat, omega0, wts)
    }

    /// Pathwise-conditioned predictions at the held-out test inputs:
    /// (mean [t], samples [t, s]).  Default: [`KernelOperator::predict_at`]
    /// on the stored test split; the XLA backend overrides with its
    /// compiled static-shape path.
    fn predict(&self, vy: &[f64], zhat: &Mat, omega0: &Mat, wts: &Mat) -> (Vec<f64>, Mat) {
        self.predict_at(self.x_test(), vy, zhat, omega0, wts)
            .expect("backend cannot predict at its stored test inputs")
    }

    /// Batched serving sweep: split `x_query` into blocks of `batch` rows,
    /// evaluate each block through [`KernelOperator::predict_at`] and
    /// concatenate in block order (an order-canonical reduction, so the
    /// result is bitwise-identical for every batch size and thread count
    /// by the per-row-independence contract above).  The third return is
    /// the number of evaluation blocks actually executed — counted here at
    /// the execution site, because backends that coalesce the whole query
    /// into one internally-parallel pass (tiled, sharded) run 1 block
    /// where the generic fan-out runs ceil(rows / batch); the serving
    /// stats report this, not a formula.  The default runs the blocks
    /// serially; the pure-Rust backends override with the threaded sweep
    /// ([`predict_batched_threaded`]).
    fn predict_batched(
        &self,
        x_query: &Mat,
        batch: usize,
        _threads: usize,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat, u64)> {
        let b = batch.max(1);
        let s = wts.cols;
        let mut mean = Vec::with_capacity(x_query.rows);
        let mut samples = Mat::zeros(0, s);
        let mut blocks = 0u64;
        let mut r0 = 0;
        while r0 < x_query.rows {
            let r1 = (r0 + b).min(x_query.rows);
            let idx: Vec<usize> = (r0..r1).collect();
            let (m, smp) = self.predict_at(&x_query.gather_rows(&idx), vy, zhat, omega0, wts)?;
            mean.extend_from_slice(&m);
            samples.append_rows(&smp);
            blocks += 1;
            r0 = r1;
        }
        Ok((mean, samples, blocks))
    }

    /// Exact MLL value+gradient if the backend has an exact path.
    fn exact_mll(&self, _y: &[f64]) -> Option<(f64, Vec<f64>)> {
        None
    }
}

/// Reusable scratch for [`KernelOperator::hv_into`]: a pool of panel
/// buffers shared by the worker threads (workers check a buffer out per
/// row-block and return it, so steady state holds one buffer per worker
/// and solver loops stop allocating per iteration).  Buffer contents are
/// fully overwritten before every read, so pooling never affects bits.
#[derive(Default)]
pub struct HvScratch {
    bufs: std::sync::Mutex<Vec<Vec<f64>>>,
}

impl HvScratch {
    /// Check out a buffer of at least `len` elements (contents arbitrary).
    pub fn take(&self, len: usize) -> Vec<f64> {
        let mut b = self.bufs.lock().unwrap().pop().unwrap_or_default();
        if b.len() < len {
            b.resize(len, 0.0);
        }
        b
    }

    /// Return a buffer to the pool.
    pub fn put(&self, buf: Vec<f64>) {
        self.bufs.lock().unwrap().push(buf);
    }
}

/// Below this many query rows the batched serving sweep stays on the
/// calling thread: spawning scoped workers costs tens of microseconds,
/// which dwarfs a small prediction batch.  Thread count never changes the
/// bits, so the threshold is purely a performance knob.
pub(crate) const SERVE_PAR_MIN_ROWS: usize = 128;

/// Threaded serving sweep shared by the pure-Rust backends: query blocks
/// of `batch` rows are distributed over the deterministic strided pool
/// ([`crate::util::parallel::parallel_map_slots`]) and concatenated in
/// block order — an order-canonical reduction.  Every row's result depends
/// only on that row, so the output is **bitwise-identical** for every
/// thread count and batch size; small queries fall back to the serial
/// in-line path (same bits).
pub(crate) fn predict_batched_threaded<T: KernelOperator + Sync>(
    op: &T,
    x_query: &Mat,
    batch: usize,
    threads: usize,
    vy: &[f64],
    zhat: &Mat,
    omega0: &Mat,
    wts: &Mat,
) -> anyhow::Result<(Vec<f64>, Mat, u64)> {
    let b = batch.max(1);
    let rows = x_query.rows;
    let s = wts.cols;
    if rows == 0 {
        return Ok((Vec::new(), Mat::zeros(0, s), 0));
    }
    let nb = (rows + b - 1) / b;
    let t = if nb <= 1 || rows < SERVE_PAR_MIN_ROWS {
        1
    } else {
        crate::util::parallel::num_threads(if threads == 0 { None } else { Some(threads) })
    };
    let parts = crate::util::parallel::parallel_map_slots(nb, t, |bi| {
        let r0 = bi * b;
        let r1 = (r0 + b).min(rows);
        let idx: Vec<usize> = (r0..r1).collect();
        op.predict_at(&x_query.gather_rows(&idx), vy, zhat, omega0, wts)
    });
    let mut mean = Vec::with_capacity(rows);
    let mut samples = Mat::zeros(0, s);
    for p in parts {
        let (m, smp) = p?;
        mean.extend_from_slice(&m);
        samples.append_rows(&smp);
    }
    Ok((mean, samples, nb as u64))
}

/// Shared Rust implementation of the RFF feature map (mirrors
/// model._rff_features): Phi = sigf sqrt(1/m) [cos(Xs W0), sin(Xs W0)].
/// Scales the rows through a transient [`ScaledX`]; backends that already
/// hold a panel cache use [`rff_features_scaled`] directly.
pub fn rff_features(x: &Mat, omega0: &Mat, hp: &Hyperparams) -> Mat {
    rff_features_scaled(&ScaledX::new(x, &hp.ell), omega0, hp.sigf)
}

/// [`rff_features`] over pre-scaled rows.  `ScaledX` rows are exactly
/// `x_i / ell` — the same elementwise expression the historical fill
/// computed inline — so routing through the cache changes no bits.
pub(crate) fn rff_features_scaled(sx: &ScaledX, omega0: &Mat, sigf: f64) -> Mat {
    let (n, d) = (sx.n(), sx.d());
    let m = omega0.cols;
    assert_eq!(omega0.rows, d);
    let amp = sigf * (1.0 / m as f64).sqrt();
    let mut phi = Mat::zeros(n, 2 * m);
    for i in 0..n {
        let row = &mut phi.data[i * 2 * m..(i + 1) * 2 * m];
        rff_fill_row(sx.row(i), omega0, amp, row);
    }
    phi
}

/// `a` with column q scaled by `w[q]` — the A·diag(w) factor shared by the
/// dense and tiled `grad_quad` implementations.
pub(crate) fn weighted_cols(a: &Mat, w: &[f64]) -> Mat {
    let mut aw = a.clone();
    for i in 0..aw.rows {
        let row = aw.row_mut(i);
        for (q, &wq) in w.iter().enumerate() {
            row[q] *= wq;
        }
    }
    aw
}

/// Noise component of `grad_quad`: 2 sigma sum_q w_q <a_q, b_q>.  Single
/// source for both backends so the formula cannot drift between them.
pub(crate) fn noise_grad(a: &Mat, b: &Mat, w: &[f64], sigma: f64) -> f64 {
    let mut dot_sum = 0.0;
    for (q, &wq) in w.iter().enumerate() {
        let mut dq = 0.0;
        for i in 0..a.rows {
            dq += a[(i, q)] * b[(i, q)];
        }
        dot_sum += wq * dq;
    }
    2.0 * sigma * dot_sum
}

/// One row of the RFF feature map over a *pre-scaled* input row
/// (`xs = x / ell`, from [`ScaledX`]): `phi[..2m] = amp [cos(z_c),
/// sin(z_c)]` with `z_c = sum_r xs_r * omega0[r, c]`.
///
/// The single source of the feature formula for `rff_features` and the
/// tiled backend's `rff_eval`/`predict` — the loop order here is
/// load-bearing: tiled↔dense parity tests require bitwise-identical
/// values, and the pre-scaled form is bit-for-bit the historical
/// `x_r / ell_r * omega0[r, c]` (division precomputed per row instead of
/// per feature), so pathwise targets are unchanged across the panel-engine
/// refactor.
pub(crate) fn rff_fill_row(xs: &[f64], omega0: &Mat, amp: f64, phi: &mut [f64]) {
    let m = omega0.cols;
    debug_assert_eq!(omega0.rows, xs.len());
    debug_assert_eq!(phi.len(), 2 * m);
    for c in 0..m {
        let mut z = 0.0;
        for r in 0..xs.len() {
            z += xs[r] * omega0[(r, c)];
        }
        phi[c] = amp * z.cos();
        phi[m + c] = amp * z.sin();
    }
}

// ---------------------------------------------------------------------------
// DenseOperator
// ---------------------------------------------------------------------------

/// Pure-Rust reference backend: materialises H once per `set_hp`, through
/// the panel engine (tile-blocked Gram-trick fills over the [`ScaledX`]
/// cache instead of one `kval` per pair).
#[derive(Clone)]
pub struct DenseOperator {
    x: Mat,
    x_test: Mat,
    s: usize,
    m: usize,
    family: KernelFamily,
    hp: Hyperparams,
    scaled: ScaledX,
    h: Mat,
    precision: Precision,
    /// H materialised with f32 panel products (values stored in f64) —
    /// present iff `precision` is F32.  `h` stays the f64 reference.
    h32: Option<Mat>,
}

impl DenseOperator {
    pub fn new(ds: &Dataset, s: usize, m: usize) -> Self {
        let hp = Hyperparams::ones(ds.spec.d);
        let scaled = ScaledX::new(&ds.x_train, &hp.ell);
        let h = panel::h_panel(&scaled, &hp, ds.spec.family);
        DenseOperator {
            x: ds.x_train.clone(),
            x_test: ds.x_test.clone(),
            s,
            m,
            family: ds.spec.family,
            hp,
            scaled,
            h,
            precision: Precision::F64,
            h32: None,
        }
    }

    /// Direct access to the materialised H (tests / diagnostics).
    pub fn h(&self) -> &Mat {
        &self.h
    }

    fn sf2(&self) -> f64 {
        self.hp.sigf * self.hp.sigf
    }

    fn rebuild_h32(&mut self) {
        self.scaled.ensure_f32();
        let mut h = panel::cross_matrix_prec(
            &self.scaled,
            &self.scaled,
            self.sf2(),
            self.family,
            Precision::F32,
        );
        h.add_diag(self.hp.noise_var());
        self.h32 = Some(h);
    }

    fn predict_at_impl(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
        prec: Precision,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        anyhow::ensure!(
            x_query.cols == self.d(),
            "predict_at: query has d = {} but the model has d = {}",
            x_query.cols,
            self.d()
        );
        assert_eq!(vy.len(), self.n());
        assert_eq!(zhat.rows, self.n());
        let mut qs = ScaledX::new(x_query, &self.hp.ell);
        if prec.is_f32() {
            qs.ensure_f32();
        }
        let kx = panel::cross_matrix_prec(&qs, &self.scaled, self.sf2(), self.family, prec);
        let mean = kx.matvec(vy);
        let phi_t = rff_features_scaled(&qs, omega0, self.hp.sigf);
        let mut samples = phi_t.matmul(wts); // [b, s]
        // + K(Xq, X) (vy - zhat)
        let mut u = zhat.clone();
        for j in 0..u.cols {
            for i in 0..u.rows {
                u[(i, j)] = vy[i] - u[(i, j)];
            }
        }
        samples.add_assign(&kx.matmul(&u));
        Ok((mean, samples))
    }
}

impl KernelOperator for DenseOperator {
    fn n(&self) -> usize {
        self.x.rows
    }
    fn d(&self) -> usize {
        self.x.cols
    }
    fn s(&self) -> usize {
        self.s
    }
    fn m(&self) -> usize {
        self.m
    }
    fn family(&self) -> KernelFamily {
        self.family
    }
    fn x(&self) -> &Mat {
        &self.x
    }
    fn x_test(&self) -> &Mat {
        &self.x_test
    }
    fn hp(&self) -> &Hyperparams {
        &self.hp
    }

    fn set_hp(&mut self, hp: &Hyperparams) {
        self.hp = hp.clone();
        self.scaled.refresh(&self.x, &hp.ell);
        self.h = panel::h_panel(&self.scaled, hp, self.family);
        if self.precision.is_f32() {
            self.rebuild_h32();
        }
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn set_precision(&mut self, prec: Precision) -> anyhow::Result<()> {
        self.precision = prec;
        match prec {
            Precision::F32 => self.rebuild_h32(),
            Precision::F64 => self.h32 = None,
        }
        Ok(())
    }

    /// Online data arrival: rank-extend the cached H in place,
    ///
    ///   H1 = [[H0, K(X0, Xn)], [K(Xn, X0), K(Xn, Xn) + sigma^2 I]],
    ///
    /// so only the new cross/corner blocks are fresh kernel evaluations —
    /// O(n1 * n_new) instead of the O(n1^2) full rebuild `set_hp` pays.
    /// The [`ScaledX`] cache grows in place first, and every block entry
    /// comes from the same panel fills a rebuild would make (panel entries
    /// are pure per-(i, j) functions of the grown cache), so the extended
    /// H is bitwise-identical to a fresh build on the concatenated data
    /// (the online parity tests assert this).
    fn extend(&mut self, x_new: &Mat) -> anyhow::Result<()> {
        anyhow::ensure!(x_new.rows > 0, "extend: empty chunk");
        anyhow::ensure!(
            x_new.cols == self.x.cols,
            "extend: chunk has d = {} but the operator holds d = {}",
            x_new.cols,
            self.x.cols
        );
        let n0 = self.x.rows;
        let nn = x_new.rows;
        let n1 = n0 + nn;
        self.scaled.extend(x_new, &self.hp.ell);
        let k_on = panel::cross_block(&self.scaled, 0..n0, n0..n1, self.sf2(), self.family); // [n0, nn]
        // lower block by symmetry: the panel fill is bitwise-symmetric
        // (commutative dot and norm sum; see the panel module docs), so
        // the transpose halves the dominant kernel-evaluation cost
        let k_no = k_on.transpose(); // [nn, n0]
        let mut k_nn = panel::cross_block(&self.scaled, n0..n1, n0..n1, self.sf2(), self.family);
        k_nn.add_diag(self.hp.noise_var());
        let mut h = Mat::zeros(n1, n1);
        for i in 0..n0 {
            let row = h.row_mut(i);
            row[..n0].copy_from_slice(self.h.row(i));
            row[n0..].copy_from_slice(k_on.row(i));
        }
        for i in 0..nn {
            let row = h.row_mut(n0 + i);
            row[..n0].copy_from_slice(k_no.row(i));
            row[n0..].copy_from_slice(k_nn.row(i));
        }
        self.h = h;
        self.x.append_rows(x_new);
        if self.precision.is_f32() {
            self.rebuild_h32();
        }
        Ok(())
    }

    fn hv(&self, v: &Mat) -> Mat {
        assert_eq!(v.rows, self.n());
        self.h.matmul(v)
    }

    fn hv_into(&self, v: &Mat, out: &mut Mat, _scratch: &HvScratch) {
        assert_eq!(v.rows, self.n());
        self.h.matmul_into(v, out);
    }

    fn hv_into_prec(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch, prec: Precision) {
        if !prec.is_f32() {
            return self.hv_into(v, out, scratch);
        }
        let h32 = self
            .h32
            .as_ref()
            .expect("f32 compute requested but set_precision(F32) was not called");
        assert_eq!(v.rows, self.n());
        h32.matmul_into(v, out);
    }

    fn k_cols(&self, idx: &[usize], u: &Mat) -> Mat {
        assert_eq!(u.rows, idx.len());
        let sb = self.scaled.gather(idx);
        let km = panel::cross_matrix(&self.scaled, &sb, self.sf2(), self.family);
        km.matmul(u)
    }

    fn k_cols_prec(&self, idx: &[usize], u: &Mat, prec: Precision) -> Mat {
        if !prec.is_f32() {
            return self.k_cols(idx, u);
        }
        assert_eq!(u.rows, idx.len());
        let sb = self.scaled.gather(idx);
        let km = panel::cross_matrix_prec(&self.scaled, &sb, self.sf2(), self.family, prec);
        km.matmul(u)
    }

    fn k_rows(&self, idx: &[usize], v: &Mat) -> Mat {
        assert_eq!(v.rows, self.n());
        let sa = self.scaled.gather(idx);
        let km = panel::cross_matrix(&sa, &self.scaled, self.sf2(), self.family);
        km.matmul(v)
    }

    fn k_rows_prec(&self, idx: &[usize], v: &Mat, prec: Precision) -> Mat {
        if !prec.is_f32() {
            return self.k_rows(idx, v);
        }
        assert_eq!(v.rows, self.n());
        let sa = self.scaled.gather(idx);
        let km = panel::cross_matrix_prec(&sa, &self.scaled, self.sf2(), self.family, prec);
        km.matmul(v)
    }

    fn grad_quad(&self, a: &Mat, b: &Mat, w: &[f64]) -> Vec<f64> {
        let (n, d) = (self.n(), self.d());
        assert_eq!(a.rows, n);
        assert_eq!(b.rows, n);
        assert_eq!(a.cols, b.cols);
        assert_eq!(w.len(), a.cols);
        // C_ij = sum_q w_q a_iq b_jq
        let aw = weighted_cols(a, w);
        let c = aw.matmul(&b.transpose()); // [n, n]
        let sf2 = self.hp.sigf * self.hp.sigf;
        let mut grad = vec![0.0; d + 2];
        for i in 0..n {
            for j in 0..n {
                let cij = c[(i, j)];
                if cij == 0.0 {
                    continue;
                }
                let sq = kernels::sqdist_scaled(self.x.row(i), self.x.row(j), &self.hp.ell);
                let h_r = dl_weight(sq, self.family);
                for k in 0..d {
                    let dlt = (self.x[(i, k)] - self.x[(j, k)]) / self.hp.ell[k];
                    grad[k] += cij * sf2 * h_r * dlt * dlt / self.hp.ell[k];
                }
                grad[d] += cij * 2.0 * sf2 * self.family.unit_cov(sq) / self.hp.sigf;
            }
        }
        grad[d + 1] = noise_grad(a, b, w, self.hp.sigma);
        grad
    }

    fn rff_eval(&self, omega0: &Mat, wts: &Mat, noise: &Mat) -> Mat {
        let phi = rff_features_scaled(&self.scaled, omega0, self.hp.sigf);
        let mut xi = phi.matmul(wts);
        assert_eq!(xi.rows, noise.rows);
        assert_eq!(xi.cols, noise.cols);
        for (o, z) in xi.data.iter_mut().zip(&noise.data) {
            *o += self.hp.sigma * z;
        }
        xi
    }

    fn predict_at(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.predict_at_impl(x_query, vy, zhat, omega0, wts, Precision::F64)
    }

    fn predict_at_prec(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
        prec: Precision,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.predict_at_impl(x_query, vy, zhat, omega0, wts, prec)
    }

    fn predict_batched(
        &self,
        x_query: &Mat,
        batch: usize,
        threads: usize,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat, u64)> {
        predict_batched_threaded(self, x_query, batch, threads, vy, zhat, omega0, wts)
    }

    fn exact_mll(&self, y: &[f64]) -> Option<(f64, Vec<f64>)> {
        let gp = crate::gp::ExactGp::fit(&self.x, y, &self.hp, self.family).ok()?;
        Some((gp.mll(y), gp.mll_grad()))
    }
}

fn dl_weight(sq: f64, family: KernelFamily) -> f64 {
    use crate::kernels::{SQRT3, SQRT5};
    match family {
        KernelFamily::Rbf => (-0.5 * sq).exp(),
        KernelFamily::Matern12 => {
            let r = sq.max(0.0).sqrt();
            (-r).exp() / r.max(1e-30)
        }
        KernelFamily::Matern32 => 3.0 * (-SQRT3 * sq.max(0.0).sqrt()).exp(),
        KernelFamily::Matern52 => {
            let r = sq.max(0.0).sqrt();
            (5.0 / 3.0) * (1.0 + SQRT5 * r) * (-SQRT5 * r).exp()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::util::rng::Rng;

    fn op() -> DenseOperator {
        let ds = data::generate(&data::spec("test").unwrap());
        DenseOperator::new(&ds, 4, 16)
    }

    #[test]
    fn hv_matches_manual() {
        let mut o = op();
        let hp = Hyperparams { ell: vec![0.8; 4], sigf: 1.1, sigma: 0.3 };
        o.set_hp(&hp);
        let mut rng = Rng::new(0);
        let v = Mat::from_fn(o.n(), o.k_width(), |_, _| rng.gaussian());
        let hv = o.hv(&v);
        // reference H from the scalar kval path: the panel engine's
        // Gram-trick values differ by ~1e-14 per entry, amplified by the
        // O(n) product accumulation — hence the tolerance
        let want = kernels::h_matrix(o.x(), &hp, o.family()).matmul(&v);
        assert!(hv.max_abs_diff(&want) < 1e-10);
        // hv_into reuses a dirty buffer bitwise
        let mut out = Mat::from_fn(o.n(), o.k_width(), |_, _| 7.5);
        let scratch = HvScratch::default();
        o.hv_into(&v, &mut out, &scratch);
        assert_eq!(out.data, hv.data);
    }

    #[test]
    fn k_cols_rows_transpose_consistency() {
        let o = op();
        let mut rng = Rng::new(1);
        let idx: Vec<usize> = (32..64).collect();
        let u = Mat::from_fn(idx.len(), o.k_width(), |_, _| rng.gaussian());
        let cols = o.k_cols(&idx, &u);
        // (K[:, I] U)[i] = sum_b K[i, I_b] U[b]
        let km = kernels::kernel_matrix(o.x(), o.x(), o.hp(), o.family());
        for i in (0..o.n()).step_by(37) {
            for q in 0..o.k_width() {
                let mut want = 0.0;
                for (bi, &bidx) in idx.iter().enumerate() {
                    want += km[(i, bidx)] * u[(bi, q)];
                }
                assert!((cols[(i, q)] - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn grad_quad_matches_finite_difference() {
        let mut o = op();
        let hp = Hyperparams { ell: vec![1.1; 4], sigf: 1.3, sigma: 0.5 };
        o.set_hp(&hp);
        let mut rng = Rng::new(2);
        let q = 3;
        let a = Mat::from_fn(o.n(), q, |_, _| rng.gaussian());
        let b = Mat::from_fn(o.n(), q, |_, _| rng.gaussian());
        let w = vec![0.5, -0.25, 1.5];
        let grad = o.grad_quad(&a, &b, &w);
        let theta0 = hp.pack();
        let eps = 1e-6;
        let qf = |theta: &[f64]| -> f64 {
            let hp = Hyperparams::unpack(theta, 4);
            let h = kernels::h_matrix(o.x(), &hp, o.family());
            let mut s = 0.0;
            for (qq, &wq) in w.iter().enumerate() {
                let hb = h.matvec(&b.col(qq));
                s += wq * crate::util::stats::dot(&a.col(qq), &hb);
            }
            s
        };
        for k in 0..theta0.len() {
            let mut tp = theta0.clone();
            tp[k] += eps;
            let mut tm = theta0.clone();
            tm[k] -= eps;
            let fd = (qf(&tp) - qf(&tm)) / (2.0 * eps);
            assert!(
                (grad[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "k={k}: {} vs {fd}",
                grad[k]
            );
        }
    }

    #[test]
    fn rff_eval_matches_feature_map() {
        let o = op();
        let mut rng = Rng::new(3);
        let (d, m, s) = (o.d(), 8, 3);
        let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let noise = Mat::from_fn(o.n(), s, |_, _| rng.gaussian());
        let xi = o.rff_eval(&omega0, &wts, &noise);
        let phi = rff_features(o.x(), &omega0, o.hp());
        let mut want = phi.matmul(&wts);
        for (w, z) in want.data.iter_mut().zip(&noise.data) {
            *w += o.hp().sigma * z;
        }
        assert!(xi.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn extended_dense_matches_rebuilt_bitwise() {
        // online contract: growing the operator chunk by chunk must be
        // indistinguishable — bitwise — from building it on the full data
        let ds = data::generate(&data::spec("test").unwrap());
        let hp = Hyperparams { ell: vec![0.8; 4], sigf: 1.1, sigma: 0.3 };
        let n0 = 100;
        let base = ds.with_train(
            ds.x_train.gather_rows(&(0..n0).collect::<Vec<_>>()),
            ds.y_train[..n0].to_vec(),
        );
        let mut grown = DenseOperator::new(&base, 4, 16);
        grown.set_hp(&hp);
        // two uneven chunks
        let c1 = ds.x_train.gather_rows(&(n0..190).collect::<Vec<_>>());
        let c2 = ds.x_train.gather_rows(&(190..ds.x_train.rows).collect::<Vec<_>>());
        grown.extend(&c1).unwrap();
        grown.extend(&c2).unwrap();
        let mut full = DenseOperator::new(&ds, 4, 16);
        full.set_hp(&hp);
        assert_eq!(grown.n(), full.n());
        assert_eq!(grown.x().data, full.x().data);
        let bit_equal = grown
            .h()
            .data
            .iter()
            .zip(&full.h().data)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(bit_equal, "extended H differs from rebuilt H in bits");
        // shape-mismatched chunks are rejected
        assert!(grown.extend(&Mat::zeros(3, 2)).is_err());
        assert!(grown.extend(&Mat::zeros(0, 4)).is_err());
    }

    #[test]
    fn predict_at_is_row_independent_and_backs_predict() {
        // serving contract: predict_at on the stored test split IS predict,
        // and splitting the query into arbitrary batches (or going through
        // predict_batched at any thread count) never changes a bit
        let mut o = op();
        o.set_hp(&Hyperparams { ell: vec![0.8; 4], sigf: 1.1, sigma: 0.3 });
        let mut rng = Rng::new(7);
        let (n, m, s) = (o.n(), 8, 3);
        let omega0 = Mat::from_fn(o.d(), m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
        let vy = rng.gaussian_vec(n);
        let (mean, samples) = o.predict(&vy, &zhat, &omega0, &wts);
        let (mean_at, samples_at) = o.predict_at(o.x_test(), &vy, &zhat, &omega0, &wts).unwrap();
        assert!(mean.iter().zip(&mean_at).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(samples.data.iter().zip(&samples_at.data).all(|(a, b)| a.to_bits() == b.to_bits()));
        // arbitrary (non-test-split) queries, split into ragged batches
        let xq = Mat::from_fn(37, o.d(), |_, _| rng.gaussian());
        let (m_once, s_once) = o.predict_at(&xq, &vy, &zhat, &omega0, &wts).unwrap();
        for batch in [1, 5, 16, 64] {
            for threads in [0, 1, 3] {
                let (m_b, s_b, blocks) = o
                    .predict_batched(&xq, batch, threads, &vy, &zhat, &omega0, &wts)
                    .unwrap();
                // dense fans out into ceil(rows / batch) executed blocks
                assert_eq!(blocks, ((xq.rows + batch - 1) / batch) as u64);
                assert!(
                    m_once.iter().zip(&m_b).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "batch={batch} threads={threads}: mean differs"
                );
                assert!(
                    s_once.data.iter().zip(&s_b.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "batch={batch} threads={threads}: samples differ"
                );
            }
        }
        // width mismatch is rejected instead of producing garbage
        assert!(o.predict_at(&Mat::zeros(3, 2), &vy, &zhat, &omega0, &wts).is_err());
    }

    #[test]
    fn predict_mean_matches_exact_gp_mean() {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut o = DenseOperator::new(&ds, 2, 8);
        let hp = Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma: 0.4 };
        o.set_hp(&hp);
        let gp = crate::gp::ExactGp::fit(&ds.x_train, &ds.y_train, &hp, o.family()).unwrap();
        let vy = gp.solve(&ds.y_train);
        let zhat = Mat::zeros(o.n(), 2);
        let omega0 = Mat::zeros(4, 8);
        let wts = Mat::zeros(16, 2);
        let (mean, _) = o.predict(&vy, &zhat, &omega0, &wts);
        let (want_mean, _) = gp.predict(&ds.x_test);
        for (a, b) in mean.iter().zip(&want_mean) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
