//! [`ShardedOperator`]: the tiled backend partitioned into S row shards,
//! each owning its own [`ScaledX`] panel cache over a contiguous range of
//! training rows — the operator layout for n ≫ 10⁵, where a single
//! monolithic panel cache (and a single global preconditioner build) is
//! the wall.
//!
//! **Partitioning rule.**  `util::parallel::shard_ranges(n, S)` assigns
//! contiguous, balanced row ranges (the first `n % S` shards get one extra
//! row).  Online arrivals ([`ShardedOperator::extend`]) append to the
//! *last* shard: earlier shard boundaries never move, so global row
//! indices — and with them the warm-start store, probe rows and the
//! partial-buffer contract — stay stable across arrivals (the last shard
//! simply grows ragged).
//!
//! **Bitwise-parity contract.**  Every product is *bitwise-identical* to a
//! [`TiledOperator`](super::TiledOperator) with the same tile size and
//! thread count, for every shard count (enforced by
//! `tests/sharded_parity.rs`).  Two facts make this work:
//!
//! 1. panel entries are pure functions of their global (i, j) pair, and a
//!    per-shard cache holds exactly the bits a monolithic cache holds for
//!    the shard's rows — so any panel or kernel-row *segment* filled from
//!    a shard cache reproduces the monolithic fill's bits;
//! 2. [`panel::apply_panel`] accumulates each output row one column at a
//!    time in ascending global j — so splitting the column sweep at shard
//!    boundaries (and re-tiling within each shard) never changes the
//!    floating-point association.
//!
//! `hv_into` therefore sweeps the shards' column ranges **in place, in
//! ascending shard order** on the existing strided pool.  That is
//! deliberately *not* "sum S independent partial buffers" — summing
//! separately accumulated partials would reassociate the additions.  The
//! multi-process communication contract is still partial-buffer-shaped:
//! [`ShardedOperator::hv_shard_partial`] computes the `hv_into`-shaped
//! [n, k] buffer contributed by one shard's columns, and a multi-node
//! deployment exchanges exactly those buffers (their fold agrees with
//! `hv` to floating-point reassociation, tested here); only the in-process
//! accumulation order upgrade is what buys bitwise equality.

use crate::data::Dataset;
use crate::kernels::panel::{self, ScaledX};
use crate::kernels::{self, Hyperparams, KernelFamily};
use crate::linalg::{micro, Mat};
use crate::util::parallel::{num_threads, parallel_reduce, parallel_row_blocks, shard_ranges};
use crate::util::stats;

use super::{dl_weight, rff_fill_row, HvScratch, KernelOperator, Precision, TiledOptions};

/// Matrix-free kernel operator over S contiguous row shards, each with its
/// own panel cache (O(n·d) total memory, like the tiled backend, but no
/// single allocation or cache scales beyond the largest shard — the full
/// X is kept only for the trait's `x()` accessor and the scalar-path
/// `grad_quad`/`exact_mll`).
pub struct ShardedOperator {
    x: Mat,
    x_test: Mat,
    s: usize,
    m: usize,
    family: KernelFamily,
    hp: Hyperparams,
    /// Per-shard panel caches; shard k owns global rows
    /// `starts[k] .. starts[k] + shards[k].n()` (contiguous, ascending).
    shards: Vec<ScaledX>,
    starts: Vec<usize>,
    tile: usize,
    threads: usize,
    precision: Precision,
}

impl ShardedOperator {
    /// Build with default tile/thread options.
    pub fn new(ds: &Dataset, s: usize, m: usize, shards: usize) -> Self {
        Self::with_options(ds, s, m, TiledOptions::default(), shards)
    }

    pub fn with_options(
        ds: &Dataset,
        s: usize,
        m: usize,
        opts: TiledOptions,
        shards: usize,
    ) -> Self {
        let hp = Hyperparams::ones(ds.spec.d);
        let x = ds.x_train.clone();
        let (parts, starts) = build_shards(&x, &hp.ell, shards);
        ShardedOperator {
            x,
            x_test: ds.x_test.clone(),
            s,
            m,
            family: ds.spec.family,
            hp,
            shards: parts,
            starts,
            tile: opts.tile.max(1),
            threads: num_threads(if opts.threads == 0 { None } else { Some(opts.threads) }),
            precision: Precision::F64,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn tile_size(&self) -> usize {
        self.tile
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Global row range owned by shard `k` (diagnostics / preconditioner
    /// shard alignment).
    pub fn shard_range(&self, k: usize) -> (usize, usize) {
        (self.starts[k], self.starts[k] + self.shards[k].n())
    }

    /// Owning shard and local index of global row `i`.
    #[inline]
    fn owner(&self, i: usize) -> (usize, usize) {
        let p = match self.starts.binary_search(&i) {
            Ok(p) => p,
            Err(p) => p - 1,
        };
        (p, i - self.starts[p])
    }

    #[inline]
    fn shard_end(&self, k: usize) -> usize {
        self.starts[k] + self.shards[k].n()
    }

    fn sf2(&self) -> f64 {
        self.hp.sigf * self.hp.sigf
    }

    /// Number of row/col tiles covering n points (scalar grad path).
    fn ntiles(&self) -> usize {
        let n = self.x.rows;
        (n + self.tile - 1) / self.tile
    }

    /// Row range of tile `b` (scalar grad path).
    fn tile_range(&self, b: usize) -> (usize, usize) {
        let n = self.x.rows;
        (b * self.tile, ((b + 1) * self.tile).min(n))
    }

    /// Fill the row-major panel K(X[i0..i1], X[j0..j1]) where the column
    /// window lives inside column shard `sx` (global base `sbase`) and the
    /// *row* range may span several shards: split it at shard boundaries
    /// and fill each segment from the owning cache.  Entries are pure per
    /// global (i, j), so this is bitwise equal to a monolithic fill.
    #[allow(clippy::too_many_arguments)]
    fn fill_panel_rows(
        &self,
        i0: usize,
        i1: usize,
        sx: &ScaledX,
        sbase: usize,
        j0: usize,
        j1: usize,
        out: &mut [f64],
        prec: Precision,
    ) {
        let w = j1 - j0;
        let sf2 = self.sf2();
        let mut i = i0;
        while i < i1 {
            let (rk, li) = self.owner(i);
            let seg_end = i1.min(self.shard_end(rk));
            panel::fill_panel_prec(
                &self.shards[rk],
                li,
                li + (seg_end - i),
                sx,
                j0 - sbase,
                j1 - sbase,
                sf2,
                self.family,
                &mut out[(i - i0) * w..(seg_end - i0) * w],
                prec,
            );
            i = seg_end;
        }
    }

    /// Fill one full-n kernel row K(a_i, X), segment-per-shard in
    /// ascending shard order — bitwise equal to the monolithic fill.
    fn fill_krow(&self, a: &ScaledX, i: usize, krow: &mut [f64], prec: Precision) {
        let sf2 = self.sf2();
        for (sk, sx) in self.shards.iter().enumerate() {
            let sbase = self.starts[sk];
            panel::fill_row_prec(
                a,
                i,
                sx,
                0,
                sf2,
                self.family,
                &mut krow[sbase..sbase + sx.n()],
                prec,
            );
        }
    }

    /// The multi-process communication contract: the `hv_into`-shaped
    /// [n, k] partial contributed by shard `shard`'s *columns*,
    /// `out = (K(X, X[cols]) + σ²·I[:, cols]) · v[cols, :]`.  A multi-node
    /// deployment computes one of these per shard owner and exchanges only
    /// these buffers; their shard-order fold equals `hv(v)` up to
    /// floating-point reassociation.  The in-process `hv_into` instead
    /// accumulates the shard sweeps in place (ascending shard order),
    /// which is what keeps it *bitwise* equal to the monolithic operator.
    pub fn hv_shard_partial(&self, shard: usize, v: &Mat, out: &mut Mat) {
        let n = self.n();
        assert!(shard < self.shards.len(), "hv_shard_partial: no shard {shard}");
        assert_eq!(v.rows, n);
        let k = v.cols;
        assert_eq!((out.rows, out.cols), (n, k));
        let noise_var = self.hp.noise_var();
        let tile = self.tile;
        let sbase = self.starts[shard];
        let send = self.shard_end(shard);
        let sx = &self.shards[shard];
        parallel_row_blocks(&mut out.data, k, tile, self.threads, |r0, rows, block| {
            block.fill(0.0);
            let mut pbuf = vec![0.0; rows * tile];
            let mut j0 = sbase;
            while j0 < send {
                let j1 = (j0 + tile).min(send);
                let w = j1 - j0;
                let panel = &mut pbuf[..rows * w];
                // the communication contract stays f64-only: exchanged
                // partials are the trusted reference a multi-node fold
                // would verify reduced-precision local compute against
                self.fill_panel_rows(r0, r0 + rows, sx, sbase, j0, j1, panel, Precision::F64);
                // the diagonal rows inside this shard's column range carry
                // the sigma² I contribution of the partial
                let (d0, d1) = (r0.max(j0), (r0 + rows).min(j1));
                for i in d0..d1 {
                    panel[(i - r0) * w + (i - j0)] += noise_var;
                }
                panel::apply_panel(panel, rows, w, j0, v, block);
                j0 = j1;
            }
        });
    }

    /// Shared body of `hv_into`/`hv_into_prec`: identical shard sweep,
    /// tiling and apply order at both precisions — only the panel fill
    /// dispatches on `prec`.
    fn hv_into_impl(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch, prec: Precision) {
        let n = self.n();
        assert_eq!(v.rows, n);
        let k = v.cols;
        assert_eq!(
            (out.rows, out.cols),
            (n, k),
            "hv_into: output is {}x{} but the product is {}x{}",
            out.rows,
            out.cols,
            n,
            k
        );
        let noise_var = self.hp.noise_var();
        let tile = self.tile;
        parallel_row_blocks(&mut out.data, k, tile, self.threads, |r0, rows, block| {
            block.fill(0.0);
            let mut pbuf = scratch.take(rows * tile);
            for (sk, sx) in self.shards.iter().enumerate() {
                let sbase = self.starts[sk];
                let send = sbase + sx.n();
                let mut j0 = sbase;
                while j0 < send {
                    let j1 = (j0 + tile).min(send);
                    let w = j1 - j0;
                    let panel = &mut pbuf[..rows * w];
                    self.fill_panel_rows(r0, r0 + rows, sx, sbase, j0, j1, panel, prec);
                    // sigma² I where the panel crosses the global diagonal
                    let (d0, d1) = (r0.max(j0), (r0 + rows).min(j1));
                    for i in d0..d1 {
                        panel[(i - r0) * w + (i - j0)] += noise_var;
                    }
                    panel::apply_panel(panel, rows, w, j0, v, block);
                    j0 = j1;
                }
            }
            scratch.put(pbuf);
        });
    }

    fn k_cols_impl(&self, idx: &[usize], u: &Mat, prec: Precision) -> Mat {
        assert_eq!(u.rows, idx.len());
        let n = self.n();
        let nb = idx.len();
        let k = u.cols;
        let sb = ScaledX::gather_parts(&self.shards, &self.starts, idx);
        let sf2 = self.sf2();
        let mut out = Mat::zeros(n, k);
        parallel_row_blocks(&mut out.data, k, self.tile, self.threads, |r0, rows, block| {
            let mut krow = vec![0.0; nb];
            for r in 0..rows {
                let i = r0 + r;
                let (rk, li) = self.owner(i);
                panel::fill_row_prec(
                    &self.shards[rk],
                    li,
                    &sb,
                    0,
                    sf2,
                    self.family,
                    &mut krow,
                    prec,
                );
                panel::apply_panel(&krow, 1, nb, 0, u, &mut block[r * k..(r + 1) * k]);
            }
        });
        out
    }

    fn k_rows_impl(&self, idx: &[usize], v: &Mat, prec: Precision) -> Mat {
        let n = self.n();
        assert_eq!(v.rows, n);
        let k = v.cols;
        let sa = ScaledX::gather_parts(&self.shards, &self.starts, idx);
        let mut out = Mat::zeros(idx.len(), k);
        let rows_total = idx.len().max(1);
        let block = (rows_total + self.threads - 1) / self.threads;
        parallel_row_blocks(&mut out.data, k, block, self.threads, |r0, rows, blk| {
            let mut krow = vec![0.0; n];
            for r in 0..rows {
                self.fill_krow(&sa, r0 + r, &mut krow, prec);
                panel::apply_panel(&krow, 1, n, 0, v, &mut blk[r * k..(r + 1) * k]);
            }
        });
        out
    }

    fn predict_at_impl(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
        prec: Precision,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        let n = self.n();
        let d = self.d();
        anyhow::ensure!(
            x_query.cols == d,
            "predict_at: query has d = {} but the model has d = {}",
            x_query.cols,
            d
        );
        let tq = x_query.rows;
        assert_eq!(vy.len(), n);
        assert_eq!(zhat.rows, n);
        assert_eq!(omega0.rows, d);
        let m = omega0.cols;
        assert_eq!(wts.rows, 2 * m);
        let s = wts.cols;
        assert_eq!(zhat.cols, s);
        let amp = self.hp.sigf * (1.0 / m as f64).sqrt();
        let mut qs = ScaledX::new(x_query, &self.hp.ell);
        if prec.is_f32() {
            qs.ensure_f32();
        }
        let width = 1 + s;
        let mut packed = Mat::zeros(tq, width);
        parallel_row_blocks(
            &mut packed.data,
            width,
            self.tile,
            self.threads,
            |r0, rows, block| {
                let mut krow = vec![0.0; n];
                let mut phi = vec![0.0; 2 * m];
                let mut corr = vec![0.0; s];
                for r in 0..rows {
                    let i = r0 + r;
                    self.fill_krow(&qs, i, &mut krow, prec);
                    let orow = &mut block[r * width..(r + 1) * width];
                    orow[0] = stats::dot(&krow, vy);
                    rff_fill_row(qs.row(i), omega0, amp, &mut phi);
                    let srow = &mut orow[1..];
                    for (c, &pc) in phi.iter().enumerate() {
                        if pc == 0.0 {
                            continue;
                        }
                        micro::axpy(srow, pc, wts.row(c));
                    }
                    for v in corr.iter_mut() {
                        *v = 0.0;
                    }
                    for j in 0..n {
                        let kj = krow[j];
                        if kj == 0.0 {
                            continue;
                        }
                        let zr = zhat.row(j);
                        for q in 0..s {
                            corr[q] += kj * (vy[j] - zr[q]);
                        }
                    }
                    for q in 0..s {
                        srow[q] += corr[q];
                    }
                }
            },
        );
        let mut mean = Vec::with_capacity(tq);
        let mut samples = Mat::zeros(tq, s);
        for i in 0..tq {
            let prow = packed.row(i);
            mean.push(prow[0]);
            samples.row_mut(i).copy_from_slice(&prow[1..]);
        }
        Ok((mean, samples))
    }
}

fn build_shards(x: &Mat, ell: &[f64], shards: usize) -> (Vec<ScaledX>, Vec<usize>) {
    let ranges = shard_ranges(x.rows, shards);
    let mut parts = Vec::with_capacity(ranges.len());
    let mut starts = Vec::with_capacity(ranges.len());
    for &(r0, r1) in &ranges {
        let rows: Vec<usize> = (r0..r1).collect();
        parts.push(ScaledX::new(&x.gather_rows(&rows), ell));
        starts.push(r0);
    }
    (parts, starts)
}

impl KernelOperator for ShardedOperator {
    fn n(&self) -> usize {
        self.x.rows
    }
    fn d(&self) -> usize {
        self.x.cols
    }
    fn s(&self) -> usize {
        self.s
    }
    fn m(&self) -> usize {
        self.m
    }
    fn family(&self) -> KernelFamily {
        self.family
    }
    fn x(&self) -> &Mat {
        &self.x
    }
    fn x_test(&self) -> &Mat {
        &self.x_test
    }
    fn hp(&self) -> &Hyperparams {
        &self.hp
    }

    fn set_hp(&mut self, hp: &Hyperparams) {
        assert_eq!(hp.ell.len(), self.d());
        self.hp = hp.clone();
        // rebuild only the caches whose lengthscale key changed (all or
        // none in practice); sigf/sigma-only steps keep every shard
        for sk in 0..self.shards.len() {
            let sn = self.shards[sk].n();
            if self.shards[sk].matches(&hp.ell, sn) {
                continue;
            }
            let r0 = self.starts[sk];
            let rows: Vec<usize> = (r0..r0 + sn).collect();
            let xs = self.x.gather_rows(&rows);
            self.shards[sk] = ScaledX::new(&xs, &hp.ell);
            if self.precision.is_f32() {
                self.shards[sk].ensure_f32();
            }
        }
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn set_precision(&mut self, prec: Precision) -> anyhow::Result<()> {
        self.precision = prec;
        if prec.is_f32() {
            for sx in &mut self.shards {
                sx.ensure_f32();
            }
        }
        Ok(())
    }

    /// Online data arrival: the appended rows go to the *last* shard, so
    /// earlier shard boundaries (and the partial-buffer contract) stay
    /// fixed and the last shard grows ragged — O(n_new·d), and the grown
    /// cache rows are bitwise-identical to a fresh build's.
    fn extend(&mut self, x_new: &Mat) -> anyhow::Result<()> {
        anyhow::ensure!(x_new.rows > 0, "extend: empty chunk");
        anyhow::ensure!(
            x_new.cols == self.x.cols,
            "extend: chunk has d = {} but the operator holds d = {}",
            x_new.cols,
            self.x.cols
        );
        self.x.append_rows(x_new);
        self.shards
            .last_mut()
            .expect("sharded operator always has at least one shard")
            .extend(x_new, &self.hp.ell);
        Ok(())
    }

    /// Thin allocating wrapper over [`ShardedOperator::hv_into`].
    fn hv(&self, v: &Mat) -> Mat {
        let mut out = Mat::zeros(self.n(), v.cols);
        self.hv_into(v, &mut out, &HvScratch::default());
        out
    }

    /// H @ V, row-block-parallel on the existing strided pool.  Each
    /// worker owns a disjoint block of output rows and sweeps the shards'
    /// column ranges in ascending shard order, re-tiling within each
    /// shard; panels are filled from the per-shard caches (row segments
    /// split at shard boundaries) and accumulated in place.  Because
    /// [`panel::apply_panel`] walks columns one ascending-j axpy at a
    /// time, the extra window boundaries at shard edges never change the
    /// association — bitwise equal to the monolithic tiled sweep.
    fn hv_into(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch) {
        self.hv_into_impl(v, out, scratch, Precision::F64);
    }

    fn hv_into_prec(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch, prec: Precision) {
        self.hv_into_impl(v, out, scratch, prec);
    }

    /// K(X, X[idx]) @ U: the batch rows are gathered *across* shards
    /// ([`ScaledX::gather_parts`], bit-equal to a monolithic gather), each
    /// output row is one panel row filled from its owning shard.
    fn k_cols(&self, idx: &[usize], u: &Mat) -> Mat {
        self.k_cols_impl(idx, u, Precision::F64)
    }

    fn k_cols_prec(&self, idx: &[usize], u: &Mat, prec: Precision) -> Mat {
        self.k_cols_impl(idx, u, prec)
    }

    /// K(X[idx], X) @ V: one full-n kernel row per batch row, filled
    /// segment-per-shard in ascending shard order, applied in ascending-j
    /// `matmul` order — bitwise equal to tiled/dense.
    fn k_rows(&self, idx: &[usize], v: &Mat) -> Mat {
        self.k_rows_impl(idx, v, Precision::F64)
    }

    fn k_rows_prec(&self, idx: &[usize], v: &Mat, prec: Precision) -> Mat {
        self.k_rows_impl(idx, v, prec)
    }

    /// Identical to the tiled backend's scalar-path gradient: the
    /// lengthscale gradient needs per-dimension differences, which the
    /// per-shard Gram caches do not expose, so this walks the full X over
    /// the same (tile, threads) grid — bitwise equal to tiled by
    /// construction.
    fn grad_quad(&self, a: &Mat, b: &Mat, w: &[f64]) -> Vec<f64> {
        let (n, d) = (self.n(), self.d());
        assert_eq!(a.rows, n);
        assert_eq!(b.rows, n);
        assert_eq!(a.cols, b.cols);
        assert_eq!(w.len(), a.cols);
        let k = a.cols;
        let aw = super::weighted_cols(a, w);
        let nb = self.ntiles();
        let sf2 = self.hp.sigf * self.hp.sigf;
        let partials = parallel_reduce(
            nb * nb,
            self.threads,
            || vec![0.0; d + 2],
            |grad, p| {
                let (bi, bj) = (p / nb, p % nb);
                let (i0, i1) = self.tile_range(bi);
                let (j0, j1) = self.tile_range(bj);
                for i in i0..i1 {
                    let awi = &aw.data[i * k..(i + 1) * k];
                    let xi = self.x.row(i);
                    for j in j0..j1 {
                        let bj_row = &b.data[j * k..(j + 1) * k];
                        let cij = stats::dot(awi, bj_row);
                        if cij == 0.0 {
                            continue;
                        }
                        let xj = self.x.row(j);
                        let sq = kernels::sqdist_scaled(xi, xj, &self.hp.ell);
                        let h_r = dl_weight(sq, self.family);
                        for kk in 0..d {
                            let dlt = (xi[kk] - xj[kk]) / self.hp.ell[kk];
                            grad[kk] += cij * sf2 * h_r * dlt * dlt / self.hp.ell[kk];
                        }
                        grad[d] += cij * 2.0 * sf2 * self.family.unit_cov(sq) / self.hp.sigf;
                    }
                }
            },
        );
        let mut grad = vec![0.0; d + 2];
        for p in &partials {
            for (g, v) in grad.iter_mut().zip(p) {
                *g += v;
            }
        }
        grad[d + 1] = super::noise_grad(a, b, w, self.hp.sigma);
        grad
    }

    /// Xi = Phi(X) wts + sigma * noise: row-parallel, the scaled feature
    /// row read from the owning shard's cache (bit-identical rows).
    fn rff_eval(&self, omega0: &Mat, wts: &Mat, noise: &Mat) -> Mat {
        let n = self.n();
        let d = self.d();
        assert_eq!(omega0.rows, d);
        let m = omega0.cols;
        assert_eq!(wts.rows, 2 * m);
        let s = wts.cols;
        assert_eq!((noise.rows, noise.cols), (n, s));
        let amp = self.hp.sigf * (1.0 / m as f64).sqrt();
        let sigma = self.hp.sigma;
        let mut out = Mat::zeros(n, s);
        parallel_row_blocks(&mut out.data, s, self.tile, self.threads, |r0, rows, block| {
            let mut phi = vec![0.0; 2 * m];
            for r in 0..rows {
                let i = r0 + r;
                let (rk, li) = self.owner(i);
                rff_fill_row(self.shards[rk].row(li), omega0, amp, &mut phi);
                let orow = &mut block[r * s..(r + 1) * s];
                for (c, &pc) in phi.iter().enumerate() {
                    if pc == 0.0 {
                        continue;
                    }
                    micro::axpy(orow, pc, wts.row(c));
                }
                let nrow = noise.row(i);
                for q in 0..s {
                    orow[q] += sigma * nrow[q];
                }
            }
        });
        out
    }

    /// Pathwise-conditioned predictions at arbitrary queries: the query
    /// kernel row is filled segment-per-shard in ascending shard order,
    /// everything downstream mirrors the tiled/dense accumulation order —
    /// bitwise equal to both.
    fn predict_at(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.predict_at_impl(x_query, vy, zhat, omega0, wts, Precision::F64)
    }

    fn predict_at_prec(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
        prec: Precision,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.predict_at_impl(x_query, vy, zhat, omega0, wts, prec)
    }

    /// `predict_at` already parallelises over query rows internally;
    /// forwarding the whole query produces identical bits (same reasoning
    /// as the tiled backend) and counts as ONE executed evaluation block.
    fn predict_batched(
        &self,
        x_query: &Mat,
        _batch: usize,
        _threads: usize,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat, u64)> {
        let blocks = if x_query.rows == 0 { 0 } else { 1 };
        let (mean, samples) = self.predict_at(x_query, vy, zhat, omega0, wts)?;
        Ok((mean, samples, blocks))
    }

    /// Exact MLL via the O(n³) Cholesky baseline on the full X (only sane
    /// at small n; callers gate via `track_exact`).
    fn exact_mll(&self, y: &[f64]) -> Option<(f64, Vec<f64>)> {
        let gp = crate::gp::ExactGp::fit(&self.x, y, &self.hp, self.family).ok()?;
        Some((gp.mll(y), gp.mll_grad()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::operators::TiledOperator;
    use crate::util::rng::Rng;

    fn ops(tile: usize, threads: usize, shards: usize) -> (ShardedOperator, TiledOperator) {
        let ds = data::generate(&data::spec("test").unwrap());
        let hp = Hyperparams { ell: vec![0.9, 1.2, 0.7, 1.1], sigf: 1.2, sigma: 0.35 };
        let mut sharded =
            ShardedOperator::with_options(&ds, 4, 16, TiledOptions { tile, threads }, shards);
        sharded.set_hp(&hp);
        let mut tiled = TiledOperator::with_options(&ds, 4, 16, TiledOptions { tile, threads });
        tiled.set_hp(&hp);
        (sharded, tiled)
    }

    #[test]
    fn shard_layout_is_contiguous_and_balanced() {
        let (op, _) = ops(64, 2, 5);
        assert_eq!(op.num_shards(), 5);
        let mut covered = 0;
        for k in 0..op.num_shards() {
            let (a, b) = op.shard_range(k);
            assert_eq!(a, covered);
            covered = b;
        }
        assert_eq!(covered, op.n());
    }

    #[test]
    fn hv_matches_tiled_bitwise_across_shard_counts() {
        for shards in [1, 2, 3, 5, 8] {
            let (sharded, tiled) = ops(48, 3, shards);
            let mut rng = Rng::new(0);
            let v = Mat::from_fn(sharded.n(), sharded.k_width(), |_, _| rng.gaussian());
            let a = sharded.hv(&v);
            let b = tiled.hv(&v);
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "shards={shards} elem {i}: {x} vs {y}");
            }
            // hv_into with a reused dirty buffer and shared scratch keeps
            // the bits
            let scratch = HvScratch::default();
            let mut out = Mat::from_fn(sharded.n(), sharded.k_width(), |_, _| -3.25);
            sharded.hv_into(&v, &mut out, &scratch);
            assert_eq!(out.data, a.data);
        }
    }

    #[test]
    fn shard_partials_fold_to_hv_within_tolerance() {
        // the multi-process contract: per-shard column partials summed in
        // shard order agree with hv up to fp reassociation (NOT bitwise —
        // that is exactly why hv_into accumulates in place instead)
        let (op, _) = ops(32, 2, 4);
        let mut rng = Rng::new(1);
        let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
        let want = op.hv(&v);
        let mut sum = Mat::zeros(op.n(), op.k_width());
        let mut part = Mat::zeros(op.n(), op.k_width());
        for sk in 0..op.num_shards() {
            op.hv_shard_partial(sk, &v, &mut part);
            for (s, p) in sum.data.iter_mut().zip(&part.data) {
                *s += p;
            }
        }
        let scale = 1.0 + want.data.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
        let err = sum.max_abs_diff(&want);
        assert!(err < 1e-10 * scale, "partial fold err {err}");
    }

    #[test]
    fn extend_appends_to_the_last_shard_only() {
        let (mut op, mut tiled) = ops(40, 2, 3);
        let before: Vec<_> = (0..op.num_shards()).map(|k| op.shard_range(k)).collect();
        let mut rng = Rng::new(2);
        let chunk = Mat::from_fn(17, op.d(), |_, _| rng.gaussian());
        op.extend(&chunk).unwrap();
        tiled.extend(&chunk).unwrap();
        for k in 0..op.num_shards() - 1 {
            assert_eq!(op.shard_range(k), before[k], "boundary {k} moved");
        }
        let last = op.num_shards() - 1;
        assert_eq!(op.shard_range(last).1, before[last].1 + 17);
        // and products still match tiled bitwise after the ragged growth
        let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
        let (a, b) = (op.hv(&v), tiled.hv(&v));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // shape-mismatched chunks are rejected
        assert!(op.extend(&Mat::zeros(2, op.d() + 1)).is_err());
        assert!(op.extend(&Mat::zeros(0, op.d())).is_err());
    }

    #[test]
    fn set_hp_keeps_caches_on_scale_only_steps() {
        let (mut op, mut tiled) = ops(64, 2, 4);
        let mut rng = Rng::new(3);
        let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
        for sigma in [0.1, 0.5, 0.9] {
            let hp = Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma };
            op.set_hp(&hp);
            tiled.set_hp(&hp);
            let (a, b) = (op.hv(&v), tiled.hv(&v));
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
