//! Admission control and staleness policy for the serving engine.
//!
//! An online arrival ([`crate::coordinator::Trainer::extend_data`])
//! invalidates a tenant's posterior snapshot mid-traffic.  The
//! [`StalenessPolicy`] decides what happens to queries that arrive before
//! the one warm refresh solve has been paid; [`ServeError`] is the typed
//! error surface of the queue/policy layer, so callers can distinguish an
//! admission rejection from a staleness refusal without string matching.

/// What the service does with queries while its artifact is data-stale
/// (the trainer's n grew past the snapshot's n).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StalenessPolicy {
    /// Reject stale-window queries with [`ServeError::Stale`]; the caller
    /// decides when to pay the refresh ([`super::PredictionService::refresh`]).
    Refuse,
    /// Answer from the retained pre-arrival snapshot (zero-padded to the
    /// grown n — numerically the pre-arrival answers), recording the
    /// served rows as stale in the stats.  No solve is paid.
    ServeStale,
    /// Pay the one warm refresh solve before answering — every answer is
    /// fresh; the first post-arrival query carries the solve latency.
    /// This is the default (the pre-policy behaviour).
    #[default]
    RefreshFirst,
}

impl StalenessPolicy {
    /// Parse a config/CLI name (single source of truth for the accepted
    /// spellings, mirroring `BackendKind::parse`).
    pub fn parse(s: &str) -> anyhow::Result<StalenessPolicy> {
        match s {
            "refuse" => Ok(StalenessPolicy::Refuse),
            "serve_stale" => Ok(StalenessPolicy::ServeStale),
            "refresh_first" => Ok(StalenessPolicy::RefreshFirst),
            other => anyhow::bail!(
                "staleness policy must be refuse|serve_stale|refresh_first, got '{other}'"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StalenessPolicy::Refuse => "refuse",
            StalenessPolicy::ServeStale => "serve_stale",
            StalenessPolicy::RefreshFirst => "refresh_first",
        }
    }
}

/// Typed errors of the queue / admission / staleness layer.  Implements
/// `std::error::Error`, so `?` converts into `anyhow::Error` at the
/// service boundary while tests can still match on the variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The admission cap would be exceeded: the request was rejected and
    /// the queue left untouched.
    QueueFull { queued_rows: usize, incoming_rows: usize, cap_rows: usize },
    /// The artifact is data-stale and the policy is
    /// [`StalenessPolicy::Refuse`].
    Stale { artifact_n: usize, data_n: usize },
    /// Query width does not match the model.
    DimensionMismatch { got: usize, want: usize },
    /// The fleet has no tenant by this name.
    UnknownTenant { name: String },
    /// A lower layer (artifact refresh / backend evaluation) failed; the
    /// chained message is preserved.
    Internal { message: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { queued_rows, incoming_rows, cap_rows } => write!(
                f,
                "queue full: {queued_rows} rows queued + {incoming_rows} incoming exceeds the \
                 admission cap of {cap_rows} rows"
            ),
            ServeError::Stale { artifact_n, data_n } => write!(
                f,
                "artifact is stale (snapshot at n = {artifact_n}, data at n = {data_n}) and the \
                 policy is 'refuse'; refresh() or switch to serve_stale|refresh_first"
            ),
            ServeError::DimensionMismatch { got, want } => {
                write!(f, "query has d = {got} but the model has d = {want}")
            }
            ServeError::UnknownTenant { name } => write!(f, "no tenant named '{name}'"),
            ServeError::Internal { message } => write!(f, "serve failed: {message}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            StalenessPolicy::Refuse,
            StalenessPolicy::ServeStale,
            StalenessPolicy::RefreshFirst,
        ] {
            assert_eq!(StalenessPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(StalenessPolicy::parse("drop").is_err());
        assert_eq!(StalenessPolicy::default(), StalenessPolicy::RefreshFirst);
    }

    #[test]
    fn errors_convert_into_anyhow_with_their_message() {
        let e = ServeError::QueueFull { queued_rows: 10, incoming_rows: 5, cap_rows: 12 };
        let any: anyhow::Error = e.into();
        assert!(any.to_string().contains("admission cap"), "{any}");
        let e = ServeError::Stale { artifact_n: 100, data_n: 150 };
        assert!(e.to_string().contains("stale"), "{e}");
    }
}
