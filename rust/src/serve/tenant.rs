//! The multi-tenant fleet: many named models served over ONE shared,
//! capacity-bounded artifact cache.
//!
//! Each tenant is a full [`PredictionService`] (its own trainer, queue,
//! policy and stats); what they share is the [`ArtifactCache`] — the
//! memory-bounded store of posterior snapshots — so fleet memory is
//! capped by the cache capacity rather than growing with tenant count,
//! and the per-tenant build/hit/eviction counters expose exactly who is
//! paying for whom under LRU pressure.
//!
//! Scheduling is deadline-aware but never mixes tenants in one
//! evaluation batch (different tenants answer from different artifacts):
//! [`ModelFleet::drain`] visits tenants ordered by their earliest
//! pending deadline (tie-break: tenant insertion order, a deterministic
//! total order) and lets each service coalesce its own queue EDF-wise.
//! Per-tenant answers therefore stay bitwise-identical to a fleet of
//! isolated services — the property `tests/serve_fleet.rs` checks.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::linalg::Mat;

use super::artifact::PosteriorArtifact;
use super::cache::{ArtifactCache, SharedArtifactCache, TenantId};
use super::policy::ServeError;
use super::queue::RequestId;
use super::stats::ServeStats;
use super::{PredictionService, RequestResult, ServeOptions};

/// The outcome of one fleet drain: answered requests in service order,
/// plus per-tenant refusals (their queues were restored, nothing is
/// dropped — the caller decides whether to refresh and re-drain).
#[derive(Debug, Default)]
pub struct FleetDrain {
    /// `(tenant name, result)` in the order served.
    pub answered: Vec<(String, RequestResult)>,
    /// Tenants whose serve was refused (e.g. stale under `refuse`); their
    /// requests remain queued.
    pub refused: Vec<(String, ServeError)>,
}

/// Named tenants over one shared artifact cache.
pub struct ModelFleet {
    cache: SharedArtifactCache,
    tenants: Vec<(String, PredictionService)>,
    next_tenant: TenantId,
}

impl ModelFleet {
    /// A fleet whose shared cache holds at most `cache_capacity` posterior
    /// snapshots across all tenants.
    pub fn new(cache_capacity: usize) -> Self {
        Self::with_cache(ArtifactCache::shared_with_capacity(cache_capacity))
    }

    /// A fleet over an existing shared cache (e.g. one also used outside
    /// the fleet).
    pub fn with_cache(cache: SharedArtifactCache) -> Self {
        ModelFleet { cache, tenants: Vec::new(), next_tenant: 1 }
    }

    /// Add a named tenant.  The trainer's private artifact cache is
    /// absorbed into the shared one (entries and counters migrate; nothing
    /// is re-counted as a build).
    pub fn add_tenant(&mut self, name: &str, mut trainer: Trainer, opts: ServeOptions) -> Result<()> {
        anyhow::ensure!(
            self.tenants.iter().all(|(t, _)| t != name),
            "fleet already has a tenant named '{name}'"
        );
        let id = self.next_tenant;
        self.next_tenant += 1;
        trainer.set_artifact_cache(self.cache.clone(), id);
        self.tenants.push((name.to_string(), PredictionService::new(trainer, opts)));
        Ok(())
    }

    /// Tenant names in insertion order.
    pub fn names(&self) -> Vec<&str> {
        self.tenants.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// The shared artifact cache (fleet-wide totals, capacity, length).
    pub fn cache(&self) -> &ArtifactCache {
        &self.cache
    }

    pub fn tenant(&self, name: &str) -> Option<&PredictionService> {
        self.tenants.iter().find(|(n, _)| n == name).map(|(_, s)| s)
    }

    pub fn tenant_mut(&mut self, name: &str) -> Option<&mut PredictionService> {
        self.tenants.iter_mut().find(|(n, _)| n == name).map(|(_, s)| s)
    }

    fn find_mut(&mut self, name: &str) -> std::result::Result<&mut PredictionService, ServeError> {
        self.tenant_mut(name).ok_or_else(|| ServeError::UnknownTenant { name: name.to_string() })
    }

    /// Admit a request for `name` with an optional deadline tick.
    pub fn enqueue(
        &mut self,
        name: &str,
        x: &Mat,
        deadline: Option<u64>,
    ) -> std::result::Result<RequestId, ServeError> {
        self.find_mut(name)?.enqueue_with_deadline(x, deadline)
    }

    /// Queued rows across every tenant.
    pub fn pending_rows(&self) -> usize {
        self.tenants.iter().map(|(_, s)| s.pending_rows()).sum()
    }

    /// Serve every queued request fleet-wide.  Tenants are visited
    /// ordered by earliest pending deadline (insertion order breaks
    /// ties); within a tenant the service drains EDF with coalesced
    /// batches.  A refused tenant keeps its queue (see [`FleetDrain`]);
    /// other tenants still get served.
    pub fn drain(&mut self) -> FleetDrain {
        let mut order: Vec<usize> = (0..self.tenants.len())
            .filter(|&i| self.tenants[i].1.pending_requests() > 0)
            .collect();
        order.sort_by_key(|&i| (self.tenants[i].1.earliest_deadline().unwrap_or(u64::MAX), i));
        let mut out = FleetDrain::default();
        for i in order {
            let (name, svc) = &mut self.tenants[i];
            match svc.drain() {
                Ok(results) => {
                    out.answered.extend(results.into_iter().map(|r| (name.clone(), r)));
                }
                Err(e) => out.refused.push((name.clone(), e)),
            }
        }
        out
    }

    /// Drain a single tenant's queue (EDF within the tenant).
    pub fn drain_tenant(
        &mut self,
        name: &str,
    ) -> std::result::Result<Vec<RequestResult>, ServeError> {
        self.find_mut(name)?.drain()
    }

    /// One-shot query against a tenant.
    pub fn predict(&mut self, name: &str, x: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        Ok(self.find_mut(name).map_err(anyhow::Error::from)?.predict(x)?)
    }

    /// Online data arrival for one tenant: its artifact is invalidated
    /// (its staleness policy governs the window); other tenants'
    /// snapshots are untouched.
    pub fn extend_data(&mut self, name: &str, x_new: &Mat, y_new: &[f64]) -> Result<()> {
        self.find_mut(name).map_err(anyhow::Error::from)?.extend_data(x_new, y_new)
    }

    /// Pay a tenant's refresh solve now (closing its staleness window).
    pub fn refresh(&mut self, name: &str) -> Result<Arc<PosteriorArtifact>> {
        self.find_mut(name).map_err(anyhow::Error::from)?.refresh()
    }

    /// A tenant's observability snapshot.
    pub fn stats(&self, name: &str) -> Option<ServeStats> {
        self.tenant(name).map(|s| s.stats())
    }
}
