//! The immutable unit of amortised serving: a posterior snapshot.

use crate::linalg::Mat;

/// Immutable snapshot of the amortised pathwise posterior at one
/// (hyperparameter, dataset-size) point: everything
/// [`crate::operators::KernelOperator::predict_at`] needs to answer
/// arbitrary queries without touching the solver again.
#[derive(Clone, Debug)]
pub struct PosteriorArtifact {
    /// Packed hyperparameters the snapshot was taken at ([ell.., sigf, sigma]).
    pub theta: Vec<f64>,
    /// Training rows at snapshot time (staleness detection, with `theta`).
    pub n: usize,
    /// Solved mean weights v_y = H⁻¹ y.
    pub vy: Vec<f64>,
    /// Pathwise-conditioning probes ẑ = H⁻¹ ξ  [n, s].
    pub zhat: Mat,
    /// RFF base frequencies of the posterior samples [d, m].
    pub omega0: Mat,
    /// RFF weights [2m, s].
    pub wts: Mat,
    /// Observation noise variance σ² at `theta` (added to sample variances).
    pub noise_var: f64,
}

impl PosteriorArtifact {
    /// The snapshot re-expressed against a *grown* training set of
    /// `n_new >= n` rows: the solved weights for rows that arrived after
    /// the snapshot are zero, so every kernel-row contraction picks up
    /// only exact `+ k·0.0` terms — the served values are numerically the
    /// pre-arrival answers.  This is what the `serve_stale` policy
    /// evaluates while a refresh is being avoided; `n` keeps the
    /// *snapshot* size so staleness stays visible.
    pub fn zero_padded(&self, n_new: usize) -> PosteriorArtifact {
        assert!(
            n_new >= self.vy.len(),
            "zero_padded: cannot shrink a snapshot ({} -> {n_new} rows)",
            self.vy.len()
        );
        let mut vy = self.vy.clone();
        vy.resize(n_new, 0.0);
        let mut zhat = self.zhat.clone();
        zhat.append_rows(&Mat::zeros(n_new - self.zhat.rows, self.zhat.cols));
        PosteriorArtifact {
            theta: self.theta.clone(),
            n: self.n,
            vy,
            zhat,
            omega0: self.omega0.clone(),
            wts: self.wts.clone(),
            noise_var: self.noise_var,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_padding_extends_rows_and_keeps_the_snapshot_n() {
        let art = PosteriorArtifact {
            theta: vec![1.0, 2.0],
            n: 3,
            vy: vec![0.5, -0.25, 4.0],
            zhat: Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f64),
            omega0: Mat::zeros(2, 4),
            wts: Mat::zeros(8, 2),
            noise_var: 0.09,
        };
        let padded = art.zero_padded(5);
        assert_eq!(padded.n, 3, "snapshot n must stay the pre-arrival size");
        assert_eq!(padded.vy, vec![0.5, -0.25, 4.0, 0.0, 0.0]);
        assert_eq!(padded.zhat.rows, 5);
        assert_eq!(&padded.zhat.data[..6], &art.zhat.data[..]);
        assert!(padded.zhat.data[6..].iter().all(|v| *v == 0.0));
        // padding to the same size is the identity
        let same = art.zero_padded(3);
        assert_eq!(same.vy, art.vy);
    }
}
