//! Prediction serving over amortised pathwise posteriors: the subsystem
//! that turns a trained [`Trainer`] into a query-answering engine.
//!
//! The paper's pathwise estimator exists to *amortise prediction*
//! (improvement i): the solved probe columns are simultaneously the
//! gradient probes and the pathwise-conditioning terms of eq. 16, so once
//! training has solved its batch, answering a query is one O(n·d) kernel
//! row plus an RFF feature row — no further linear solves.  Three pieces
//! make that a serving path instead of a test-split-only evaluation:
//!
//! * [`PosteriorArtifact`] — an immutable snapshot of the amortised state
//!   (solved `v_y`, `zhat`, `omega0`, `wts`, hyperparameters), exported by
//!   [`Trainer::posterior_artifact`];
//! * [`ArtifactCache`] — a small LRU keyed on (hyperparameter bits, n),
//!   mirroring the preconditioner cache, so repeated serve/refresh cycles
//!   at unchanged hyperparameters never re-solve;
//! * [`PredictionService`] — request batching (queries accumulate into
//!   blocks of a configurable batch size), threaded batched evaluation on
//!   the deterministic strided pool with order-canonical reductions
//!   (bitwise-identical for every thread count; serial fallback for small
//!   batches), throughput counters, and staleness handling: an online
//!   arrival ([`Trainer::extend_data`]) invalidates the artifact, and the
//!   next query refreshes it from the warm-carried solution store — one
//!   warm solve, not a cold restart.
//!
//! Acceptance bar (after Maddox et al. 2021, "When are Iterative GPs
//! Reliably Accurate?"): the serving path is parity-tested against the
//! evaluate path — `tests/serve_parity.rs` demands bitwise-equal
//! mean/variance on the stored test split, tiled == dense bitwise at
//! arbitrary query points, and thread-count invariance.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::gp::{metrics, pathwise_variances, Metrics};
use crate::kernels::Hyperparams;
use crate::linalg::Mat;
use crate::operators::KernelOperator;

// ---------------------------------------------------------------------------
// PosteriorArtifact
// ---------------------------------------------------------------------------

/// Immutable snapshot of the amortised pathwise posterior at one
/// (hyperparameter, dataset-size) point: everything
/// [`crate::operators::KernelOperator::predict_at`] needs to answer
/// arbitrary queries without touching the solver again.
#[derive(Clone, Debug)]
pub struct PosteriorArtifact {
    /// Packed hyperparameters the snapshot was taken at ([ell.., sigf, sigma]).
    pub theta: Vec<f64>,
    /// Training rows at snapshot time (staleness detection, with `theta`).
    pub n: usize,
    /// Solved mean weights v_y = H⁻¹ y.
    pub vy: Vec<f64>,
    /// Pathwise-conditioning probes ẑ = H⁻¹ ξ  [n, s].
    pub zhat: Mat,
    /// RFF base frequencies of the posterior samples [d, m].
    pub omega0: Mat,
    /// RFF weights [2m, s].
    pub wts: Mat,
    /// Observation noise variance σ² at `theta` (added to sample variances).
    pub noise_var: f64,
}

// ---------------------------------------------------------------------------
// ArtifactCache
// ---------------------------------------------------------------------------

/// Cache key: exact f64 bit patterns of the packed hyperparameters plus
/// the training size n — the same staleness notion as the preconditioner
/// cache: the outer loop revisits the *same* theta several times per
/// serve/refresh cycle, any genuine hyperparameter step changes the bits,
/// and online data arrival grows n at unchanged hyperparameters.
type ArtifactKey = (Vec<u64>, usize);

fn artifact_key(hp: &Hyperparams, n: usize) -> ArtifactKey {
    (hp.pack().iter().map(|x| x.to_bits()).collect(), n)
}

#[derive(Default)]
struct ArtifactInner {
    /// Small LRU list (linear scan; capacity is single digits).
    entries: Vec<(ArtifactKey, Arc<PosteriorArtifact>)>,
    builds: u64,
    hits: u64,
}

/// Coordinator-owned store of posterior snapshots, mirroring
/// [`crate::solvers::PreconditionerCache`]: LRU over (hyperparameter bits,
/// n), interior-mutable so diagnostics can read counters behind `&self`.
pub struct ArtifactCache {
    inner: Mutex<ArtifactInner>,
    cap: usize,
}

impl Default for ArtifactCache {
    /// Two snapshots: a `PosteriorArtifact` holds O(n·s) state (`zhat`
    /// plus `vy`), and every evaluation publishes one, so a training-only
    /// run at large n must not pin a deep history it will never read.
    /// Serving fetches the *latest* theta; one extra slot covers the
    /// serve → tweak → serve-back cycle.
    fn default() -> Self {
        ArtifactCache::with_capacity(2)
    }
}

impl std::fmt::Debug for ArtifactCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("ArtifactCache")
            .field("entries", &inner.entries.len())
            .field("builds", &inner.builds)
            .field("hits", &inner.hits)
            .finish()
    }
}

impl ArtifactCache {
    /// `cap` snapshots are retained (LRU eviction).
    pub fn with_capacity(cap: usize) -> Self {
        ArtifactCache { inner: Mutex::new(ArtifactInner::default()), cap: cap.max(1) }
    }

    /// The cached snapshot for (hp, n), if any (counts a hit and refreshes
    /// its LRU position).
    pub fn get(&self, hp: &Hyperparams, n: usize) -> Option<Arc<PosteriorArtifact>> {
        let key = artifact_key(hp, n);
        let mut inner = self.inner.lock().unwrap();
        let pos = inner.entries.iter().position(|(k, _)| *k == key)?;
        inner.hits += 1;
        let entry = inner.entries.remove(pos);
        let art = entry.1.clone();
        inner.entries.push(entry); // LRU: move to back
        Some(art)
    }

    /// Publish a freshly built snapshot (replacing any entry with the same
    /// key — the new one was built from newer solver state).
    pub fn insert(&self, hp: &Hyperparams, n: usize, art: Arc<PosteriorArtifact>) {
        let key = artifact_key(hp, n);
        let mut inner = self.inner.lock().unwrap();
        if let Some(pos) = inner.entries.iter().position(|(k, _)| *k == key) {
            inner.entries.remove(pos);
        } else if inner.entries.len() >= self.cap {
            inner.entries.remove(0);
        }
        inner.builds += 1;
        inner.entries.push((key, art));
    }

    /// Drop every snapshot.  Called on online data arrival: all entries
    /// were built for the old n (the n in the key already prevents wrong
    /// reuse; invalidation frees the memory).  Counters are preserved.
    pub fn invalidate_all(&self) {
        self.inner.lock().unwrap().entries.clear();
    }

    /// Snapshots built so far (telemetry / regression tests).
    pub fn builds(&self) -> u64 {
        self.inner.lock().unwrap().builds
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// PredictionService
// ---------------------------------------------------------------------------

/// Serving knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Rows per evaluation block: queued queries are served in blocks of
    /// this size (the unit of the threaded sweep).
    pub batch: usize,
    /// Worker threads for the batched sweep (0 = auto: `IGP_THREADS`, else
    /// all cores).  Results are bitwise-identical for every value.
    pub threads: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { batch: 64, threads: 0 }
    }
}

/// Throughput / cache counters of one service instance.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Query rows answered.
    pub rows_served: u64,
    /// Logical evaluation blocks (ceil(rows / batch) per request) — the
    /// unit of the generic fan-out.  Backends may coalesce: the tiled
    /// backend serves each request in one internally row-parallel pass.
    pub batches: u64,
    /// Posterior snapshots built (solve-refreshes) over the trainer's life.
    pub artifact_builds: u64,
    /// Snapshot cache hits over the trainer's life.
    pub artifact_hits: u64,
}

/// A query-answering engine over a trained [`Trainer`].
///
/// The service owns the trainer: queries are answered from the cached
/// [`PosteriorArtifact`] (refreshed lazily — at most one solve per
/// (hyperparameter, n) point), and online arrivals go through
/// [`PredictionService::extend_data`], after which the next query refreshes
/// the artifact from the warm-carried solution store.
pub struct PredictionService {
    trainer: Trainer,
    opts: ServeOptions,
    /// Accumulated-but-unserved query rows ([`PredictionService::enqueue`]).
    pending: Mat,
    rows_served: u64,
    batches: u64,
}

impl PredictionService {
    pub fn new(trainer: Trainer, opts: ServeOptions) -> Self {
        let d = trainer.operator().d();
        let opts = ServeOptions { batch: opts.batch.max(1), ..opts };
        PredictionService { trainer, opts, pending: Mat::zeros(0, d), rows_served: 0, batches: 0 }
    }

    pub fn options(&self) -> &ServeOptions {
        &self.opts
    }

    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    /// Mutable trainer access (e.g. to keep training between serves).
    /// Anything that changes hyperparameters or data is picked up on the
    /// next query through the artifact key.
    pub fn trainer_mut(&mut self) -> &mut Trainer {
        &mut self.trainer
    }

    /// Hand the trainer back (e.g. to checkpoint it).
    pub fn into_trainer(self) -> Trainer {
        self.trainer
    }

    /// Queue query rows for the next [`PredictionService::flush`].
    pub fn enqueue(&mut self, x: &Mat) -> Result<()> {
        anyhow::ensure!(
            x.cols == self.pending.cols,
            "enqueue: query has d = {} but the model has d = {}",
            x.cols,
            self.pending.cols
        );
        self.pending.append_rows(x);
        Ok(())
    }

    /// Queued-but-unserved rows.
    pub fn pending_rows(&self) -> usize {
        self.pending.rows
    }

    /// Serve every queued row (in enqueue order): (mean, variance).
    pub fn flush(&mut self) -> Result<(Vec<f64>, Vec<f64>)> {
        let d = self.pending.cols;
        let queued = std::mem::replace(&mut self.pending, Mat::zeros(0, d));
        self.serve(&queued)
    }

    /// One-shot query: posterior mean and predictive variance (with
    /// observation noise) at each row of `x_query`.
    pub fn predict(&mut self, x_query: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        self.serve(x_query)
    }

    /// Predict and score against known targets.
    pub fn score(&mut self, x_query: &Mat, y_true: &[f64]) -> Result<Metrics> {
        anyhow::ensure!(
            x_query.rows == y_true.len(),
            "score: {} query rows but {} targets",
            x_query.rows,
            y_true.len()
        );
        let (mean, var) = self.serve(x_query)?;
        Ok(metrics(&mean, &var, y_true))
    }

    /// Online data arrival: grow the trainer in place.  The current
    /// artifact is invalidated ([`Trainer::extend_data`] clears the cache
    /// and the key's n changes); the next query triggers one *warm* solve
    /// from the carried solution store.
    pub fn extend_data(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<()> {
        self.trainer.extend_data(x_new, y_new)
    }

    /// Force an artifact refresh now (e.g. to pay the solve outside the
    /// serving hot path).  Cached snapshots make this free when nothing
    /// changed.
    pub fn refresh(&mut self) -> Result<Arc<PosteriorArtifact>> {
        self.trainer.posterior_artifact()
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            rows_served: self.rows_served,
            batches: self.batches,
            artifact_builds: self.trainer.artifact_cache().builds(),
            artifact_hits: self.trainer.artifact_cache().hits(),
        }
    }

    fn serve(&mut self, x_query: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        anyhow::ensure!(
            x_query.cols == self.trainer.operator().d(),
            "predict: query has d = {} but the model has d = {}",
            x_query.cols,
            self.trainer.operator().d()
        );
        if x_query.rows == 0 {
            return Ok((Vec::new(), Vec::new()));
        }
        let art = self.trainer.posterior_artifact()?;
        let (mean, samples) = self.trainer.operator().predict_batched(
            x_query,
            self.opts.batch,
            self.opts.threads,
            &art.vy,
            &art.zhat,
            &art.omega0,
            &art.wts,
        )?;
        let var = pathwise_variances(&samples, art.noise_var);
        self.rows_served += x_query.rows as u64;
        self.batches += ((x_query.rows + self.opts.batch - 1) / self.opts.batch) as u64;
        Ok((mean, var))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_artifact(tag: f64) -> Arc<PosteriorArtifact> {
        Arc::new(PosteriorArtifact {
            theta: vec![tag],
            n: 1,
            vy: vec![tag],
            zhat: Mat::zeros(1, 1),
            omega0: Mat::zeros(1, 1),
            wts: Mat::zeros(2, 1),
            noise_var: 0.0,
        })
    }

    fn hp(sigma: f64) -> Hyperparams {
        Hyperparams { ell: vec![1.0, 2.0], sigf: 1.0, sigma }
    }

    #[test]
    fn cache_hits_on_same_key_and_misses_on_changes() {
        let cache = ArtifactCache::default();
        assert!(cache.get(&hp(0.3), 10).is_none());
        cache.insert(&hp(0.3), 10, dummy_artifact(1.0));
        assert_eq!(cache.builds(), 1);
        let a = cache.get(&hp(0.3), 10).expect("hit");
        assert_eq!(a.theta, vec![1.0]);
        assert_eq!(cache.hits(), 1);
        // hyperparameter bits and n are both part of the key
        assert!(cache.get(&hp(0.31), 10).is_none());
        assert!(cache.get(&hp(0.3), 11).is_none());
    }

    #[test]
    fn cache_replaces_same_key_and_evicts_lru() {
        let cache = ArtifactCache::with_capacity(2);
        cache.insert(&hp(0.1), 5, dummy_artifact(1.0));
        cache.insert(&hp(0.1), 5, dummy_artifact(2.0)); // replace, not grow
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.get(&hp(0.1), 5).unwrap().theta, vec![2.0]);
        cache.insert(&hp(0.2), 5, dummy_artifact(3.0));
        // touch 0.1 so 0.2 becomes the LRU victim of the next insert
        let _ = cache.get(&hp(0.1), 5);
        cache.insert(&hp(0.3), 5, dummy_artifact(4.0));
        assert!(cache.get(&hp(0.2), 5).is_none());
        assert!(cache.get(&hp(0.1), 5).is_some());
        assert!(cache.get(&hp(0.3), 5).is_some());
    }

    #[test]
    fn cache_invalidate_keeps_counters() {
        let cache = ArtifactCache::default();
        cache.insert(&hp(0.1), 5, dummy_artifact(1.0));
        let _ = cache.get(&hp(0.1), 5);
        cache.invalidate_all();
        assert!(cache.is_empty());
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 1);
        assert!(cache.get(&hp(0.1), 5).is_none());
    }
}
