//! Prediction serving over amortised pathwise posteriors: the subsystem
//! that turns trained [`Trainer`]s into a traffic-facing query engine.
//!
//! The paper's pathwise estimator exists to *amortise prediction*
//! (improvement i): the solved probe columns are simultaneously the
//! gradient probes and the pathwise-conditioning terms of eq. 16, so once
//! training has solved its batch, answering a query is one O(n·d) kernel
//! row plus an RFF feature row — no further linear solves.  The layers
//! that make that a serving engine instead of a test-split evaluation:
//!
//! * [`artifact`] — [`PosteriorArtifact`], the immutable snapshot of the
//!   amortised state, exported by [`Trainer::posterior_artifact`];
//! * [`cache`] — the tenant-aware LRU ([`ArtifactCache`]): one shared,
//!   capacity-bounded store backs a whole fleet, with per-tenant
//!   build/hit/eviction accounting;
//! * [`queue`] — [`RequestQueue`]: admission-capped accumulation of
//!   requests with optional logical deadline ticks, drained
//!   earliest-deadline-first;
//! * [`policy`] — [`StalenessPolicy`] (`refuse | serve_stale |
//!   refresh_first`) decides what happens to queries that arrive between
//!   an online arrival and the one warm refresh solve, and [`ServeError`]
//!   is the typed error surface;
//! * [`stats`] — [`ServeStats`]: deterministic counters plus a
//!   fixed-bucket enqueue→answer latency histogram (p50/p99, rows/sec);
//! * [`tenant`] — [`ModelFleet`]: many named tenants over one shared
//!   cache;
//! * [`PredictionService`] (here) — the per-tenant engine tying them
//!   together: deadline-aware micro-batching over the deterministic
//!   strided pool with order-canonical reductions, so queue-served
//!   answers are **bitwise-identical** to serving each request alone for
//!   every batch size, thread count and interleaving.
//!
//! Acceptance bar (after Maddox et al. 2021, "When are Iterative GPs
//! Reliably Accurate?"): the serving path is parity-tested against the
//! evaluate path — `tests/serve_parity.rs` demands bitwise-equal
//! mean/variance on the stored test split, and `tests/serve_fleet.rs`
//! extends the bar across interleaved multi-tenant traffic.

pub mod artifact;
pub mod cache;
pub mod policy;
pub mod queue;
pub mod stats;
pub mod tenant;

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::fault::{mat_finite, slice_finite, FaultError, FaultPlan, FaultSite, RecoveryStats, Supervisor};
use crate::gp::{metrics, pathwise_variances, Metrics};
use crate::linalg::Mat;
use crate::operators::KernelOperator;

pub use artifact::PosteriorArtifact;
pub use cache::{ArtifactCache, SharedArtifactCache, TenantCacheStats, TenantId};
pub use policy::{ServeError, StalenessPolicy};
pub use queue::{PendingRequest, RequestId, RequestQueue};
pub use stats::{LatencyHistogram, ServeCounters, ServeStats, LATENCY_BUCKETS};
pub use tenant::{FleetDrain, ModelFleet};

// ---------------------------------------------------------------------------
// ServeOptions
// ---------------------------------------------------------------------------

/// Serving knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Rows per evaluation block: queued queries are coalesced and served
    /// in blocks of this size (the unit of the threaded sweep).
    pub batch: usize,
    /// Worker threads for the batched sweep (0 = auto: `IGP_THREADS`, else
    /// all cores).  Results are bitwise-identical for every value.
    pub threads: usize,
    /// What to do with queries that arrive while the artifact is
    /// data-stale (between an online arrival and its refresh solve).
    pub policy: StalenessPolicy,
    /// Admission cap: maximum queued rows across pending requests
    /// (0 = unbounded).  Requests past the cap are rejected with
    /// [`ServeError::QueueFull`].
    pub queue_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            batch: 64,
            threads: 0,
            policy: StalenessPolicy::RefreshFirst,
            queue_cap: 0,
        }
    }
}

// ---------------------------------------------------------------------------
// PredictionService
// ---------------------------------------------------------------------------

/// One answered request, routed back by id ([`PredictionService::drain`]).
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: RequestId,
    pub deadline: Option<u64>,
    /// Posterior mean per query row (request row order preserved).
    pub mean: Vec<f64>,
    /// Predictive variance (with observation noise) per query row.
    pub var: Vec<f64>,
    /// Enqueue→answer latency.
    pub latency_ns: u64,
    /// Whether the answer came from a marked-stale snapshot
    /// (`serve_stale` policy inside a staleness window).
    pub stale: bool,
    /// Whether the answer was *degraded*: a `refresh_first` refresh
    /// failed and the service fell back to the retained stale snapshot
    /// instead of erroring (graceful degradation under faults).  Implies
    /// `stale`.
    pub degraded: bool,
}

/// A query-answering engine over a trained [`Trainer`].
///
/// The service owns the trainer: queries are answered from the cached
/// [`PosteriorArtifact`] (refreshed lazily — at most one solve per
/// (hyperparameter, n) point).  Requests accumulate through
/// [`PredictionService::enqueue_with_deadline`] under an admission cap and
/// are drained earliest-deadline-first, coalesced into batch-sized
/// evaluation blocks that split and merge across request boundaries while
/// preserving per-request row order — bitwise-identical to serving each
/// request alone, by the per-row-independence contract of
/// [`KernelOperator::predict_at`].  Online arrivals go through
/// [`PredictionService::extend_data`]; queries inside the staleness window
/// are refused, served stale, or held for the one warm refresh solve
/// according to [`ServeOptions::policy`].
pub struct PredictionService {
    trainer: Trainer,
    opts: ServeOptions,
    queue: RequestQueue,
    rows_served: u64,
    batches: u64,
    stale_rows_served: u64,
    rejected: u64,
    latency: LatencyHistogram,
    serve_ns: u64,
    /// The artifact most recently served or refreshed (the candidate
    /// `serve_stale` snapshot for the next arrival).
    last_served: Option<Arc<PosteriorArtifact>>,
    /// Pre-arrival snapshot retained while data-stale (`serve_stale`).
    stale_snapshot: Option<Arc<PosteriorArtifact>>,
    /// `stale_snapshot` zero-padded to the current n (lazily built, reset
    /// when n grows again).
    stale_padded: Option<Arc<PosteriorArtifact>>,
    /// Set by [`PredictionService::extend_data`], cleared by the refresh
    /// that answers it.  Arrivals driven directly through
    /// [`PredictionService::trainer_mut`] bypass the policy window and
    /// behave like `refresh_first` (the artifact key already forces the
    /// warm solve).
    data_stale: bool,
    /// Rows answered degraded (failed refresh downgraded to stale).
    degraded_rows_served: u64,
    /// Serve-side fault schedule + recovery counters.  Armed together
    /// with the owned trainer ([`PredictionService::arm_faults`]); the
    /// service's operation tick positions the shared schedule.
    supervisor: Supervisor,
    /// Service operations performed (each is one schedule step).
    chaos_tick: u64,
}

impl PredictionService {
    pub fn new(trainer: Trainer, opts: ServeOptions) -> Self {
        let d = trainer.operator().d();
        let opts = ServeOptions { batch: opts.batch.max(1), ..opts };
        let queue = RequestQueue::new(d, opts.queue_cap);
        PredictionService {
            trainer,
            opts,
            queue,
            rows_served: 0,
            batches: 0,
            stale_rows_served: 0,
            rejected: 0,
            latency: LatencyHistogram::default(),
            serve_ns: 0,
            last_served: None,
            stale_snapshot: None,
            stale_padded: None,
            data_stale: false,
            degraded_rows_served: 0,
            supervisor: Supervisor::default(),
            chaos_tick: 0,
        }
    }

    /// Arm deterministic fault injection on the service *and* its owned
    /// trainer (one shared schedule: in serve mode the service's
    /// operation tick positions it — each flush/drain/predict/refresh is
    /// one step).  Unarmed services pay a single `is_none` check per
    /// operation.
    pub fn arm_faults(&mut self, plan: Arc<FaultPlan>) {
        self.trainer.arm_faults(plan.clone());
        self.supervisor.arm(plan);
    }

    /// Combined recovery counters: the trainer's (solve retries,
    /// fallbacks, rollbacks, …) plus the serve layer's (artifact
    /// quarantine rebuilds).  Degraded servings are counted in
    /// [`ServeCounters::degraded_rows_served`] instead — a degradation
    /// answers traffic, it does not repair anything.
    pub fn recovery_stats(&self) -> RecoveryStats {
        let t = self.trainer.recovery_stats();
        let s = self.supervisor.stats;
        RecoveryStats {
            retries: t.retries + s.retries,
            wasted_epochs: t.wasted_epochs + s.wasted_epochs,
            fallback_solves: t.fallback_solves + s.fallback_solves,
            rollbacks: t.rollbacks + s.rollbacks,
            target_repairs: t.target_repairs + s.target_repairs,
            cache_rebuilds: t.cache_rebuilds + s.cache_rebuilds,
        }
    }

    /// Advance the fault schedule by one service operation (no-op
    /// unarmed).
    fn tick_chaos(&mut self) {
        if self.supervisor.armed() {
            self.supervisor.set_step(self.chaos_tick);
            self.chaos_tick += 1;
        }
    }

    pub fn options(&self) -> &ServeOptions {
        &self.opts
    }

    /// Switch the staleness policy mid-traffic (queued requests are kept;
    /// the new policy applies from the next serve).
    pub fn set_policy(&mut self, policy: StalenessPolicy) {
        self.opts.policy = policy;
    }

    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    /// Mutable trainer access (e.g. to keep training between serves).
    /// Anything that changes hyperparameters or data is picked up on the
    /// next query through the artifact key.
    pub fn trainer_mut(&mut self) -> &mut Trainer {
        &mut self.trainer
    }

    /// Hand the trainer back (e.g. to checkpoint it).
    pub fn into_trainer(self) -> Trainer {
        self.trainer
    }

    /// Queue query rows for the next [`PredictionService::flush`] (no
    /// deadline; back-compat convenience over
    /// [`PredictionService::enqueue_with_deadline`]).
    pub fn enqueue(&mut self, x: &Mat) -> Result<()> {
        self.enqueue_with_deadline(x, None)?;
        Ok(())
    }

    /// Admit a request with an optional logical deadline tick (smaller =
    /// sooner; `None` = served after every deadlined request).  Typed
    /// rejections: [`ServeError::QueueFull`] past the admission cap
    /// (counted in [`ServeCounters::rejected`]),
    /// [`ServeError::DimensionMismatch`] on width mismatch — either way
    /// the queue is untouched.
    pub fn enqueue_with_deadline(
        &mut self,
        x: &Mat,
        deadline: Option<u64>,
    ) -> std::result::Result<RequestId, ServeError> {
        match self.queue.push(x, deadline) {
            Ok(id) => Ok(id),
            Err(e) => {
                if matches!(e, ServeError::QueueFull { .. }) {
                    self.rejected += 1;
                }
                Err(e)
            }
        }
    }

    /// Queued-but-unserved rows.
    pub fn pending_rows(&self) -> usize {
        self.queue.rows()
    }

    /// Queued-but-unserved requests.
    pub fn pending_requests(&self) -> usize {
        self.queue.len()
    }

    /// The earliest deadline among queued requests (fleet scheduling).
    pub fn earliest_deadline(&self) -> Option<u64> {
        self.queue.earliest_deadline()
    }

    /// Serve every queued row in enqueue order: (mean, variance)
    /// concatenated across requests.  On error nothing is answered and
    /// **nothing is dropped** — the queue is restored exactly as it was.
    pub fn flush(&mut self) -> Result<(Vec<f64>, Vec<f64>)> {
        self.tick_chaos();
        let items = self.queue.take_fifo();
        match self.serve_requests(&items) {
            Ok((mean, var, _, _)) => {
                self.queue.acknowledge(&items);
                Ok((mean, var))
            }
            Err(e) => {
                self.queue.restore(items);
                Err(e.into())
            }
        }
    }

    /// Serve every queued request earliest-deadline-first, coalesced into
    /// batch-sized evaluation blocks across request boundaries, results
    /// routed back by request id with per-request latency.  Answers are
    /// bitwise-identical to serving each request alone.  On error the
    /// queue is restored untouched.
    pub fn drain(&mut self) -> std::result::Result<Vec<RequestResult>, ServeError> {
        self.tick_chaos();
        let items = self.queue.take_edf();
        match self.serve_requests(&items) {
            Ok((mean, var, stale, degraded)) => {
                self.queue.acknowledge(&items);
                let mut out = Vec::with_capacity(items.len());
                let mut r0 = 0;
                for p in &items {
                    let r1 = r0 + p.x.rows;
                    let latency_ns = p.enqueued.elapsed().as_nanos() as u64;
                    self.latency.record(latency_ns);
                    out.push(RequestResult {
                        id: p.id,
                        deadline: p.deadline,
                        mean: mean[r0..r1].to_vec(),
                        var: var[r0..r1].to_vec(),
                        latency_ns,
                        stale,
                        degraded,
                    });
                    r0 = r1;
                }
                Ok(out)
            }
            Err(e) => {
                self.queue.restore(items);
                Err(e)
            }
        }
    }

    /// One-shot query: posterior mean and predictive variance (with
    /// observation noise) at each row of `x_query`.  Records one
    /// enqueue→answer latency sample (enqueue and answer coincide).
    pub fn predict(&mut self, x_query: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        self.tick_chaos();
        let t0 = Instant::now();
        let (mean, var, _, _) = self.serve_rows(x_query)?;
        if x_query.rows > 0 {
            self.latency.record(t0.elapsed().as_nanos() as u64);
        }
        Ok((mean, var))
    }

    /// Predict and score against known targets.
    pub fn score(&mut self, x_query: &Mat, y_true: &[f64]) -> Result<Metrics> {
        anyhow::ensure!(
            x_query.rows == y_true.len(),
            "score: {} query rows but {} targets",
            x_query.rows,
            y_true.len()
        );
        let (mean, var) = self.predict(x_query)?;
        Ok(metrics(&mean, &var, y_true))
    }

    /// Online data arrival: grow the trainer in place.  The artifact is
    /// invalidated ([`Trainer::extend_data`] drops this tenant's cache
    /// entries and the key's n changes); what happens to queries before
    /// the warm refresh solve is governed by [`ServeOptions::policy`].
    pub fn extend_data(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<()> {
        self.trainer.extend_data(x_new, y_new)?;
        if !self.data_stale {
            // retain the pre-arrival snapshot: it is what `serve_stale`
            // answers from during the staleness window
            self.stale_snapshot = self.last_served.take();
        }
        self.data_stale = true;
        self.stale_padded = None; // n grew again: re-pad lazily
        Ok(())
    }

    /// Force an artifact refresh now (e.g. to pay the warm solve outside
    /// the serving hot path).  Clears the staleness window; cached
    /// snapshots make this free when nothing changed.
    pub fn refresh(&mut self) -> Result<Arc<PosteriorArtifact>> {
        self.tick_chaos();
        let art = self.refresh_artifact().map_err(anyhow::Error::from)?;
        Ok(art)
    }

    pub fn stats(&self) -> ServeStats {
        let tc = self.trainer.artifact_cache().tenant_stats(self.trainer.tenant());
        ServeStats {
            counters: ServeCounters {
                rows_served: self.rows_served,
                batches: self.batches,
                artifact_builds: tc.builds,
                artifact_hits: tc.hits,
                artifact_evictions: tc.evictions,
                stale_rows_served: self.stale_rows_served,
                rejected: self.rejected,
                degraded_rows_served: self.degraded_rows_served,
            },
            latency: self.latency.clone(),
            serve_ns: self.serve_ns,
        }
    }

    /// Serve a batch of queued requests as one coalesced sweep (the
    /// evaluation blocks split/merge across request boundaries; per-row
    /// independence keeps the bits identical to serving each alone).
    fn serve_requests(
        &mut self,
        items: &[PendingRequest],
    ) -> std::result::Result<(Vec<f64>, Vec<f64>, bool, bool), ServeError> {
        let d = self.trainer.operator().d();
        let mut x_all = Mat::zeros(0, d);
        for p in items {
            x_all.append_rows(&p.x);
        }
        self.serve_rows(&x_all)
    }

    /// The serve core: resolve the artifact under the staleness policy,
    /// run the batched sweep, account rows/blocks/latency.
    fn serve_rows(
        &mut self,
        x_query: &Mat,
    ) -> std::result::Result<(Vec<f64>, Vec<f64>, bool, bool), ServeError> {
        let d = self.trainer.operator().d();
        if x_query.cols != d {
            return Err(ServeError::DimensionMismatch { got: x_query.cols, want: d });
        }
        if x_query.rows == 0 {
            return Ok((Vec::new(), Vec::new(), false, false));
        }
        let (art, stale, degraded) = self.artifact_for_serve()?;
        let t0 = Instant::now();
        let (mean, samples, blocks) = self
            .trainer
            .operator()
            .predict_batched(
                x_query,
                self.opts.batch,
                self.opts.threads,
                &art.vy,
                &art.zhat,
                &art.omega0,
                &art.wts,
            )
            .map_err(|e| ServeError::Internal { message: format!("{e:#}") })?;
        self.serve_ns += t0.elapsed().as_nanos() as u64;
        let var = pathwise_variances(&samples, art.noise_var);
        self.rows_served += x_query.rows as u64;
        self.batches += blocks;
        if stale {
            self.stale_rows_served += x_query.rows as u64;
        }
        if degraded {
            self.degraded_rows_served += x_query.rows as u64;
        }
        Ok((mean, var, stale, degraded))
    }

    /// Resolve the artifact to answer from.  Fresh path: the cache (hit,
    /// or one lazy build on hyperparameter drift).  Inside a staleness
    /// window, the policy decides: refuse (typed error, counted), serve
    /// the retained zero-padded snapshot, or pay the warm refresh — and a
    /// *failed* `refresh_first` refresh degrades gracefully to the stale
    /// snapshot (flagged `degraded`) instead of erroring, when one exists.
    /// Returns (artifact, stale, degraded).
    fn artifact_for_serve(
        &mut self,
    ) -> std::result::Result<(Arc<PosteriorArtifact>, bool, bool), ServeError> {
        if !self.data_stale {
            let art = self.fetch_artifact()?;
            return Ok((art, false, false));
        }
        match self.opts.policy {
            StalenessPolicy::Refuse => {
                self.rejected += 1;
                Err(ServeError::Stale {
                    artifact_n: self.stale_snapshot.as_ref().map(|a| a.n).unwrap_or(0),
                    data_n: self.trainer.operator().n(),
                })
            }
            StalenessPolicy::ServeStale => match self.padded_stale() {
                Some(p) => Ok((p, true, false)),
                // nothing was ever served: there is no stale answer to
                // give, so the first query pays the (warm) build
                None => self.refresh_artifact().map(|a| (a, false, false)),
            },
            StalenessPolicy::RefreshFirst => {
                let refreshed = if self.supervisor.fires(FaultSite::Refresh) {
                    // injected refresh failure (chaos `refresh` site)
                    Err(ServeError::Internal { message: "injected refresh failure".into() })
                } else {
                    self.refresh_artifact()
                };
                match refreshed {
                    Ok(a) => Ok((a, false, false)),
                    Err(e) => match self.padded_stale() {
                        // graceful degradation: downgrade to serve_stale
                        // rather than failing the queued traffic
                        Some(p) => Ok((p, true, true)),
                        None => Err(ServeError::Internal {
                            message: FaultError::RefreshFailed { detail: e.to_string() }
                                .to_string(),
                        }),
                    },
                }
            }
        }
    }

    /// The retained pre-arrival snapshot, zero-padded to the current n
    /// (rebuilt lazily when n grows again); `None` when nothing was ever
    /// served before the arrival.
    fn padded_stale(&mut self) -> Option<Arc<PosteriorArtifact>> {
        let snap = self.stale_snapshot.clone()?;
        let n = self.trainer.operator().n();
        match self.stale_padded.clone() {
            Some(p) if p.vy.len() == n => Some(p),
            _ => {
                let p = Arc::new(snap.zero_padded(n));
                self.stale_padded = Some(p.clone());
                Some(p)
            }
        }
    }

    /// Fetch/refresh through the trainer and close the staleness window.
    fn refresh_artifact(&mut self) -> std::result::Result<Arc<PosteriorArtifact>, ServeError> {
        let art = self.fetch_artifact()?;
        self.data_stale = false;
        self.stale_snapshot = None;
        self.stale_padded = None;
        Ok(art)
    }

    fn fetch_artifact(&mut self) -> std::result::Result<Arc<PosteriorArtifact>, ServeError> {
        let mut art = self
            .trainer
            .posterior_artifact()
            .map_err(|e| ServeError::Internal { message: format!("{e:#}") })?;
        // artifact validation (armed only — the unarmed hot path never
        // scans): a poisoned cache entry is quarantined tenant-wide and
        // rebuilt once; persistent poison is a typed error
        if self.supervisor.armed() && !artifact_finite(&art) {
            let tenant = self.trainer.tenant();
            self.trainer.artifact_cache().invalidate_tenant(tenant);
            self.supervisor.stats.cache_rebuilds += 1;
            art = self
                .trainer
                .posterior_artifact()
                .map_err(|e| ServeError::Internal { message: format!("{e:#}") })?;
            if !artifact_finite(&art) {
                return Err(ServeError::Internal {
                    message: FaultError::ArtifactPoisoned { tenant }.to_string(),
                });
            }
        }
        self.last_served = Some(art.clone());
        Ok(art)
    }
}

/// Full finite scan of a posterior snapshot (chaos validation only — the
/// unarmed serve path never calls this).
fn artifact_finite(a: &PosteriorArtifact) -> bool {
    slice_finite(&a.vy) && mat_finite(&a.zhat) && mat_finite(&a.wts) && a.noise_var.is_finite()
}
