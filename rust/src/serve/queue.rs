//! The request queue: admission-capped accumulation of query requests
//! with optional deadline ticks, drained earliest-deadline-first.
//!
//! A request keeps its rows contiguous and remembers its enqueue instant
//! (the start of the enqueue→answer latency the stats layer records).
//! Deadlines are *logical ticks* (u64, smaller = sooner, `None` = latest)
//! — the scheduler only needs an ordering, and logical ticks keep the
//! drain order deterministic for the parity tests.  Ties break by arrival
//! id, so the drain order is a total order and every interleaving serves
//! bitwise-identical answers.

use std::time::Instant;

use crate::linalg::Mat;

use super::policy::ServeError;

/// Identifies one enqueued request within its service; results are routed
/// back under this id.
pub type RequestId = u64;

/// One queued request: `x.rows` query rows awaiting an answer.
#[derive(Clone, Debug)]
pub struct PendingRequest {
    pub id: RequestId,
    pub x: Mat,
    /// Logical deadline tick (`None` = no deadline: served after every
    /// deadlined request).
    pub deadline: Option<u64>,
    /// When the request entered the queue (latency measurement origin).
    pub enqueued: Instant,
}

impl PendingRequest {
    /// The EDF sort key: deadline first (`None` last), arrival id breaks
    /// ties deterministically.
    fn edf_key(&self) -> (u64, RequestId) {
        (self.deadline.unwrap_or(u64::MAX), self.id)
    }
}

/// FIFO accumulation + EDF drain with a row-count admission cap.
pub struct RequestQueue {
    d: usize,
    /// Admission cap in *rows* across all queued requests (0 = unbounded).
    cap_rows: usize,
    items: Vec<PendingRequest>,
    rows: usize,
    next_id: RequestId,
}

impl RequestQueue {
    pub fn new(d: usize, cap_rows: usize) -> Self {
        RequestQueue { d, cap_rows, items: Vec::new(), rows: 0, next_id: 0 }
    }

    /// Admit a request.  Rejections (wrong width, cap exceeded) leave the
    /// queue untouched; zero-row requests are admitted and answered empty.
    pub fn push(&mut self, x: &Mat, deadline: Option<u64>) -> Result<RequestId, ServeError> {
        if x.cols != self.d {
            return Err(ServeError::DimensionMismatch { got: x.cols, want: self.d });
        }
        if self.cap_rows > 0 && self.rows + x.rows > self.cap_rows {
            return Err(ServeError::QueueFull {
                queued_rows: self.rows,
                incoming_rows: x.rows,
                cap_rows: self.cap_rows,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.rows += x.rows;
        self.items.push(PendingRequest { id, x: x.clone(), deadline, enqueued: Instant::now() });
        Ok(id)
    }

    /// Queued-but-unserved rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Queued requests.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Take every queued request in arrival order (the `flush` contract:
    /// answers concatenate in enqueue order).
    pub fn take_fifo(&mut self) -> Vec<PendingRequest> {
        self.rows = 0;
        std::mem::take(&mut self.items)
    }

    /// Take every queued request earliest-deadline-first (deadline tick,
    /// then arrival id — a deterministic total order).
    pub fn take_edf(&mut self) -> Vec<PendingRequest> {
        let mut items = self.take_fifo();
        items.sort_by_key(PendingRequest::edf_key);
        items
    }

    /// Put requests back (the error path of a failed serve: nothing was
    /// answered, so nothing may be dropped).  Arrival order is restored
    /// from the ids, which also merges correctly with anything enqueued
    /// since the take.
    pub fn restore(&mut self, items: Vec<PendingRequest>) {
        self.rows += items.iter().map(|p| p.x.rows).sum::<usize>();
        self.items.extend(items);
        self.items.sort_by_key(|p| p.id);
    }

    /// The earliest deadline among queued requests (`None` when the queue
    /// is empty or entirely deadline-free) — the fleet orders tenants by
    /// this.
    pub fn earliest_deadline(&self) -> Option<u64> {
        self.items.iter().filter_map(|p| p.deadline).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(rows: usize) -> Mat {
        Mat::zeros(rows, 3)
    }

    #[test]
    fn edf_orders_by_deadline_then_arrival() {
        let mut queue = RequestQueue::new(3, 0);
        let a = queue.push(&q(1), Some(9)).unwrap();
        let b = queue.push(&q(2), None).unwrap();
        let c = queue.push(&q(1), Some(2)).unwrap();
        let d = queue.push(&q(3), Some(9)).unwrap();
        assert_eq!(queue.rows(), 7);
        assert_eq!(queue.earliest_deadline(), Some(2));
        let order: Vec<RequestId> = queue.take_edf().iter().map(|p| p.id).collect();
        // deadline 2, then the two deadline-9 requests in arrival order,
        // then the deadline-free request
        assert_eq!(order, vec![c, a, d, b]);
        assert_eq!(queue.rows(), 0);
        assert!(queue.is_empty());
    }

    #[test]
    fn fifo_take_keeps_arrival_order() {
        let mut queue = RequestQueue::new(3, 0);
        let a = queue.push(&q(1), Some(5)).unwrap();
        let b = queue.push(&q(1), Some(1)).unwrap();
        let order: Vec<RequestId> = queue.take_fifo().iter().map(|p| p.id).collect();
        assert_eq!(order, vec![a, b]);
    }

    #[test]
    fn admission_cap_rejects_without_disturbing_the_queue() {
        let mut queue = RequestQueue::new(3, 4);
        queue.push(&q(3), None).unwrap();
        let err = queue.push(&q(2), None).unwrap_err();
        assert_eq!(
            err,
            ServeError::QueueFull { queued_rows: 3, incoming_rows: 2, cap_rows: 4 }
        );
        assert_eq!(queue.rows(), 3);
        // a fitting request is still admitted after the rejection
        queue.push(&q(1), None).unwrap();
        assert_eq!(queue.rows(), 4);
        // width mismatches are typed too
        assert_eq!(
            queue.push(&Mat::zeros(1, 2), None).unwrap_err(),
            ServeError::DimensionMismatch { got: 2, want: 3 }
        );
    }

    #[test]
    fn restore_rebuilds_arrival_order_and_row_count() {
        let mut queue = RequestQueue::new(3, 0);
        let a = queue.push(&q(2), Some(7)).unwrap();
        let b = queue.push(&q(1), Some(1)).unwrap();
        let taken = queue.take_edf();
        assert_eq!(queue.rows(), 0);
        queue.restore(taken);
        assert_eq!(queue.rows(), 3);
        let c = queue.push(&q(1), None).unwrap();
        let order: Vec<RequestId> = queue.take_fifo().iter().map(|p| p.id).collect();
        assert_eq!(order, vec![a, b, c]);
    }
}
