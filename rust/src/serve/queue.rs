//! The request queue: admission-capped accumulation of query requests
//! with optional deadline ticks, drained earliest-deadline-first.
//!
//! A request keeps its rows contiguous and remembers its enqueue instant
//! (the start of the enqueue→answer latency the stats layer records).
//! Deadlines are *logical ticks* (u64, smaller = sooner, `None` = latest)
//! — the scheduler only needs an ordering, and logical ticks keep the
//! drain order deterministic for the parity tests.  Ties break by arrival
//! id, so the drain order is a total order and every interleaving serves
//! bitwise-identical answers.

use std::time::Instant;

use crate::linalg::Mat;

use super::policy::ServeError;

/// Identifies one enqueued request within its service; results are routed
/// back under this id.
pub type RequestId = u64;

/// One queued request: `x.rows` query rows awaiting an answer.
#[derive(Clone, Debug)]
pub struct PendingRequest {
    pub id: RequestId,
    pub x: Mat,
    /// Logical deadline tick (`None` = no deadline: served after every
    /// deadlined request).
    pub deadline: Option<u64>,
    /// When the request entered the queue (latency measurement origin).
    pub enqueued: Instant,
}

impl PendingRequest {
    /// The EDF sort key: deadline first, *every* deadlined request before
    /// every deadline-free one (an explicit `u64::MAX` deadline still
    /// outranks `None` — the leading bool carries the distinction the
    /// numeric value cannot), arrival id breaks ties deterministically.
    fn edf_key(&self) -> (bool, u64, RequestId) {
        (self.deadline.is_none(), self.deadline.unwrap_or(u64::MAX), self.id)
    }
}

/// FIFO accumulation + EDF drain with a row-count admission cap.
pub struct RequestQueue {
    d: usize,
    /// Admission cap in *rows* across all queued requests (0 = unbounded).
    cap_rows: usize,
    items: Vec<PendingRequest>,
    rows: usize,
    /// Rows taken by an in-progress drain and not yet resolved (restored
    /// on failure or [`RequestQueue::acknowledge`]d on success).
    /// Admission counts them, so a failed drain can always restore its
    /// items without blowing past the cap.
    in_flight_rows: usize,
    next_id: RequestId,
}

impl RequestQueue {
    pub fn new(d: usize, cap_rows: usize) -> Self {
        RequestQueue { d, cap_rows, items: Vec::new(), rows: 0, in_flight_rows: 0, next_id: 0 }
    }

    /// Admit a request.  Rejections (wrong width, cap exceeded) leave the
    /// queue untouched; zero-row requests are admitted and answered empty.
    /// The cap covers queued *and* in-flight rows: requests taken by a
    /// drain still hold their reservation until the drain succeeds
    /// ([`RequestQueue::acknowledge`]) or restores.
    pub fn push(&mut self, x: &Mat, deadline: Option<u64>) -> Result<RequestId, ServeError> {
        if x.cols != self.d {
            return Err(ServeError::DimensionMismatch { got: x.cols, want: self.d });
        }
        let held = self.rows + self.in_flight_rows;
        if self.cap_rows > 0 && held + x.rows > self.cap_rows {
            return Err(ServeError::QueueFull {
                queued_rows: held,
                incoming_rows: x.rows,
                cap_rows: self.cap_rows,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.rows += x.rows;
        self.items.push(PendingRequest { id, x: x.clone(), deadline, enqueued: Instant::now() });
        Ok(id)
    }

    /// Queued-but-unserved rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Queued requests.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Take every queued request in arrival order (the `flush` contract:
    /// answers concatenate in enqueue order).  The taken rows stay
    /// reserved against the admission cap until the drain resolves.
    pub fn take_fifo(&mut self) -> Vec<PendingRequest> {
        self.in_flight_rows += std::mem::replace(&mut self.rows, 0);
        std::mem::take(&mut self.items)
    }

    /// Take every queued request earliest-deadline-first (deadline tick,
    /// then arrival id — a deterministic total order).
    pub fn take_edf(&mut self) -> Vec<PendingRequest> {
        let mut items = self.take_fifo();
        items.sort_by_key(PendingRequest::edf_key);
        items
    }

    /// Put requests back (the error path of a failed serve: nothing was
    /// answered, so nothing may be dropped).  Arrival order is restored
    /// from the ids, which also merges correctly with anything enqueued
    /// since the take; the restored rows move back from the in-flight
    /// reservation to the queued count, so the cap stays exact.
    pub fn restore(&mut self, items: Vec<PendingRequest>) {
        let restored: usize = items.iter().map(|p| p.x.rows).sum();
        self.in_flight_rows = self.in_flight_rows.saturating_sub(restored);
        self.rows += restored;
        self.items.extend(items);
        self.items.sort_by_key(|p| p.id);
    }

    /// Release the admission reservation of successfully served requests
    /// (the success path of a drain; the failure path is
    /// [`RequestQueue::restore`]).
    pub fn acknowledge(&mut self, items: &[PendingRequest]) {
        let served: usize = items.iter().map(|p| p.x.rows).sum();
        self.in_flight_rows = self.in_flight_rows.saturating_sub(served);
    }

    /// The earliest deadline among queued requests (`None` when the queue
    /// is empty or entirely deadline-free) — the fleet orders tenants by
    /// this.
    pub fn earliest_deadline(&self) -> Option<u64> {
        self.items.iter().filter_map(|p| p.deadline).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(rows: usize) -> Mat {
        Mat::zeros(rows, 3)
    }

    #[test]
    fn edf_orders_by_deadline_then_arrival() {
        let mut queue = RequestQueue::new(3, 0);
        let a = queue.push(&q(1), Some(9)).unwrap();
        let b = queue.push(&q(2), None).unwrap();
        let c = queue.push(&q(1), Some(2)).unwrap();
        let d = queue.push(&q(3), Some(9)).unwrap();
        assert_eq!(queue.rows(), 7);
        assert_eq!(queue.earliest_deadline(), Some(2));
        let order: Vec<RequestId> = queue.take_edf().iter().map(|p| p.id).collect();
        // deadline 2, then the two deadline-9 requests in arrival order,
        // then the deadline-free request
        assert_eq!(order, vec![c, a, d, b]);
        assert_eq!(queue.rows(), 0);
        assert!(queue.is_empty());
    }

    #[test]
    fn fifo_take_keeps_arrival_order() {
        let mut queue = RequestQueue::new(3, 0);
        let a = queue.push(&q(1), Some(5)).unwrap();
        let b = queue.push(&q(1), Some(1)).unwrap();
        let order: Vec<RequestId> = queue.take_fifo().iter().map(|p| p.id).collect();
        assert_eq!(order, vec![a, b]);
    }

    #[test]
    fn admission_cap_rejects_without_disturbing_the_queue() {
        let mut queue = RequestQueue::new(3, 4);
        queue.push(&q(3), None).unwrap();
        let err = queue.push(&q(2), None).unwrap_err();
        assert_eq!(
            err,
            ServeError::QueueFull { queued_rows: 3, incoming_rows: 2, cap_rows: 4 }
        );
        assert_eq!(queue.rows(), 3);
        // a fitting request is still admitted after the rejection
        queue.push(&q(1), None).unwrap();
        assert_eq!(queue.rows(), 4);
        // width mismatches are typed too
        assert_eq!(
            queue.push(&Mat::zeros(1, 2), None).unwrap_err(),
            ServeError::DimensionMismatch { got: 2, want: 3 }
        );
    }

    #[test]
    fn explicit_max_deadline_outranks_deadline_free() {
        let mut queue = RequestQueue::new(3, 0);
        let free = queue.push(&q(1), None).unwrap();
        let max = queue.push(&q(1), Some(u64::MAX)).unwrap();
        let order: Vec<RequestId> = queue.take_edf().iter().map(|p| p.id).collect();
        // a deadline — even the largest one — beats no deadline at all,
        // regardless of arrival order
        assert_eq!(order, vec![max, free]);
    }

    #[test]
    fn failed_drain_holds_the_admission_reservation() {
        let mut queue = RequestQueue::new(3, 4);
        queue.push(&q(3), Some(1)).unwrap();
        let taken = queue.take_edf();
        // mid-drain the taken rows still hold their reservation: only one
        // more row fits, so a restore can never blow past the cap
        assert_eq!(
            queue.push(&q(2), None).unwrap_err(),
            ServeError::QueueFull { queued_rows: 3, incoming_rows: 2, cap_rows: 4 }
        );
        queue.push(&q(1), None).unwrap();
        queue.restore(taken);
        assert_eq!(queue.rows(), 4);
        // a successful drain releases the reservation instead
        let taken = queue.take_edf();
        queue.acknowledge(&taken);
        assert_eq!(queue.rows(), 0);
        queue.push(&q(4), None).unwrap();
        assert_eq!(queue.rows(), 4);
    }

    /// Satellite property test: random interleavings of pushes, failing
    /// drains (take → restore) and succeeding drains (take → acknowledge)
    /// against a reference model.  Invariants: arrival ids are exact and
    /// strictly increasing, EDF drain order equals the model's total
    /// order, restore preserves every request bit-for-bit, and queued +
    /// in-flight rows never exceed the admission cap.
    #[test]
    fn property_interleaved_drains_preserve_edf_order_ids_and_cap() {
        use crate::util::rng::Rng;
        const CAP: usize = 12;
        for seed in 0..32u64 {
            let mut rng = Rng::new(0xED_F0 ^ seed);
            let mut queue = RequestQueue::new(2, CAP);
            // model: (id, rows, deadline) of every request believed queued
            let mut model: Vec<(RequestId, usize, Option<u64>)> = Vec::new();
            let mut expected_next_id: RequestId = 0;
            for _ in 0..120 {
                match rng.next_u64() % 10 {
                    // push (weighted heaviest)
                    0..=5 => {
                        let rows = (rng.next_u64() % 4) as usize; // 0..=3 (0-row legal)
                        let deadline = match rng.next_u64() % 4 {
                            0 => None,
                            1 => Some(u64::MAX), // the None-tie corner
                            _ => Some(rng.next_u64() % 5),
                        };
                        let queued: usize = model.iter().map(|m| m.1).sum();
                        let x = Mat::zeros(rows, 2);
                        match queue.push(&x, deadline) {
                            Ok(id) => {
                                assert_eq!(id, expected_next_id, "ids are arrival-exact");
                                assert!(queued + rows <= CAP, "over-admission");
                                expected_next_id += 1;
                                model.push((id, rows, deadline));
                            }
                            Err(ServeError::QueueFull { .. }) => {
                                assert!(queued + rows > CAP, "under-admission");
                            }
                            Err(e) => panic!("unexpected rejection: {e}"),
                        }
                    }
                    // failing drain: take EDF, verify order, restore
                    6..=8 => {
                        let taken = queue.take_edf();
                        let mut expect = model.clone();
                        expect.sort_by_key(|(id, _, dl)| {
                            (dl.is_none(), dl.unwrap_or(u64::MAX), *id)
                        });
                        let got: Vec<(RequestId, usize, Option<u64>)> =
                            taken.iter().map(|p| (p.id, p.x.rows, p.deadline)).collect();
                        assert_eq!(got, expect, "EDF drain order drifted (seed {seed})");
                        queue.restore(taken);
                        assert_eq!(queue.len(), model.len(), "restore dropped a request");
                        assert_eq!(
                            queue.rows(),
                            model.iter().map(|m| m.1).sum::<usize>(),
                            "restore drifted the row account"
                        );
                    }
                    // succeeding drain: take FIFO, verify arrival order,
                    // acknowledge (requests leave the system)
                    _ => {
                        let taken = queue.take_fifo();
                        let got: Vec<RequestId> = taken.iter().map(|p| p.id).collect();
                        let expect: Vec<RequestId> = model.iter().map(|m| m.0).collect();
                        assert_eq!(got, expect, "FIFO order drifted (seed {seed})");
                        queue.acknowledge(&taken);
                        model.clear();
                        assert_eq!(queue.rows(), 0);
                    }
                }
            }
            // end state: a full drain returns exactly the model, in order
            let got: Vec<RequestId> = queue.take_fifo().iter().map(|p| p.id).collect();
            let expect: Vec<RequestId> = model.iter().map(|m| m.0).collect();
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn restore_rebuilds_arrival_order_and_row_count() {
        let mut queue = RequestQueue::new(3, 0);
        let a = queue.push(&q(2), Some(7)).unwrap();
        let b = queue.push(&q(1), Some(1)).unwrap();
        let taken = queue.take_edf();
        assert_eq!(queue.rows(), 0);
        queue.restore(taken);
        assert_eq!(queue.rows(), 3);
        let c = queue.push(&q(1), None).unwrap();
        let order: Vec<RequestId> = queue.take_fifo().iter().map(|p| p.id).collect();
        assert_eq!(order, vec![a, b, c]);
    }
}
