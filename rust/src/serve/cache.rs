//! Tenant-aware LRU store of posterior snapshots.
//!
//! One shared, capacity-bounded cache backs an entire model fleet: keys
//! carry a [`TenantId`] alongside the exact hyperparameter bits and the
//! training size n, so N tenants cost one LRU instead of N, and the
//! per-tenant build / hit / eviction counters let each tenant's service
//! report what the shared store did on its behalf.  Mirrors
//! [`crate::solvers::PreconditionerCache`]: interior-mutable behind a
//! `Mutex` so diagnostics read counters through `&self`, and shared
//! across owners as an `Arc` ([`SharedArtifactCache`]).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::kernels::Hyperparams;

use super::artifact::PosteriorArtifact;

/// Identifies one tenant (model / hyperparameter version) inside a shared
/// cache.  A trainer outside any fleet uses tenant 0 on its private cache.
pub type TenantId = u64;

/// Shared handle: the coordinator and the fleet both hold one.
pub type SharedArtifactCache = Arc<ArtifactCache>;

/// Cache key: tenant plus the exact f64 bit patterns of the packed
/// hyperparameters plus the training size n — the same staleness notion as
/// the preconditioner cache: the outer loop revisits the *same* theta
/// several times per serve/refresh cycle, any genuine hyperparameter step
/// changes the bits, and online data arrival grows n at unchanged
/// hyperparameters.
type ArtifactKey = (TenantId, Vec<u64>, usize);

fn artifact_key(tenant: TenantId, hp: &Hyperparams, n: usize) -> ArtifactKey {
    (tenant, hp.pack().iter().map(|x| x.to_bits()).collect(), n)
}

/// Per-tenant accounting of what the shared store did on a tenant's
/// behalf.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantCacheStats {
    /// Snapshots built (published) for this tenant.
    pub builds: u64,
    /// Cache hits served to this tenant.
    pub hits: u64,
    /// This tenant's entries evicted by LRU pressure (from any tenant).
    pub evictions: u64,
}

#[derive(Default)]
struct ArtifactInner {
    /// Small LRU list (linear scan; capacity is single digits).
    entries: Vec<(ArtifactKey, Arc<PosteriorArtifact>)>,
    builds: u64,
    hits: u64,
    evictions: u64,
    per_tenant: BTreeMap<TenantId, TenantCacheStats>,
}

impl ArtifactInner {
    fn tenant(&mut self, t: TenantId) -> &mut TenantCacheStats {
        self.per_tenant.entry(t).or_default()
    }

    /// Evict the LRU entry, charging the eviction to the entry's owner.
    fn evict_front(&mut self) {
        let (key, _) = self.entries.remove(0);
        self.evictions += 1;
        self.tenant(key.0).evictions += 1;
    }
}

/// Store of posterior snapshots: LRU over (tenant, hyperparameter bits,
/// n), interior-mutable so diagnostics can read counters behind `&self`.
pub struct ArtifactCache {
    inner: Mutex<ArtifactInner>,
    cap: usize,
}

impl Default for ArtifactCache {
    /// Two snapshots: a `PosteriorArtifact` holds O(n·s) state (`zhat`
    /// plus `vy`), and every evaluation publishes one, so a training-only
    /// run at large n must not pin a deep history it will never read.
    /// Serving fetches the *latest* theta; one extra slot covers the
    /// serve → tweak → serve-back cycle.  Fleets size the shared cache
    /// explicitly ([`ArtifactCache::with_capacity`]).
    fn default() -> Self {
        ArtifactCache::with_capacity(2)
    }
}

impl std::fmt::Debug for ArtifactCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.guard();
        f.debug_struct("ArtifactCache")
            .field("entries", &inner.entries.len())
            .field("builds", &inner.builds)
            .field("hits", &inner.hits)
            .field("evictions", &inner.evictions)
            .finish()
    }
}

impl ArtifactCache {
    /// Lock the store, recovering from mutex poisoning: a panicking
    /// holder can only have been mid-bookkeeping, every mutation leaves
    /// the entry list structurally valid, and a fleet must keep serving
    /// its healthy tenants after one tenant's thread dies — so the
    /// supervisor-era policy is recover-and-continue, not propagate.
    fn guard(&self) -> std::sync::MutexGuard<'_, ArtifactInner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// `cap` snapshots are retained (LRU eviction), across all tenants.
    pub fn with_capacity(cap: usize) -> Self {
        ArtifactCache { inner: Mutex::new(ArtifactInner::default()), cap: cap.max(1) }
    }

    /// A shareable handle (fleet construction / coordinator injection).
    pub fn shared_with_capacity(cap: usize) -> SharedArtifactCache {
        Arc::new(ArtifactCache::with_capacity(cap))
    }

    /// The cached snapshot for (tenant, hp, n), if any (counts a hit and
    /// refreshes its LRU position).
    pub fn get(
        &self,
        tenant: TenantId,
        hp: &Hyperparams,
        n: usize,
    ) -> Option<Arc<PosteriorArtifact>> {
        let key = artifact_key(tenant, hp, n);
        let mut inner = self.guard();
        let pos = inner.entries.iter().position(|(k, _)| *k == key)?;
        inner.hits += 1;
        inner.tenant(tenant).hits += 1;
        let entry = inner.entries.remove(pos);
        let art = entry.1.clone();
        inner.entries.push(entry); // LRU: move to back
        Some(art)
    }

    /// Publish a freshly built snapshot (replacing any entry with the same
    /// key — the new one was built from newer solver state).  A full cache
    /// evicts its LRU entry, charged to the evicted entry's tenant.
    pub fn insert(
        &self,
        tenant: TenantId,
        hp: &Hyperparams,
        n: usize,
        art: Arc<PosteriorArtifact>,
    ) {
        let key = artifact_key(tenant, hp, n);
        let mut inner = self.guard();
        if let Some(pos) = inner.entries.iter().position(|(k, _)| *k == key) {
            inner.entries.remove(pos);
        } else if inner.entries.len() >= self.cap {
            inner.evict_front();
        }
        inner.builds += 1;
        inner.tenant(tenant).builds += 1;
        inner.entries.push((key, art));
    }

    /// Drop one tenant's snapshots.  Called on that tenant's online data
    /// arrival: its entries were built for the old n (the n in the key
    /// already prevents wrong reuse; invalidation frees the memory), and
    /// the other tenants' snapshots must survive.  Counters are preserved.
    pub fn invalidate_tenant(&self, tenant: TenantId) {
        self.guard().entries.retain(|(k, _)| k.0 != tenant);
    }

    /// Drop every snapshot, every tenant.  Counters are preserved.
    pub fn invalidate_all(&self) {
        self.guard().entries.clear();
    }

    /// Adopt another cache's entries and counters under `tenant` — the
    /// migration performed when a trainer with a private cache joins a
    /// fleet.  Entries are re-keyed to `tenant` and inserted respecting
    /// this cache's capacity (LRU order preserved, evictions charged as
    /// usual); build/hit counters transfer so "artifacts built over the
    /// trainer's life" stays a lifetime number, and are *not* re-counted
    /// as fresh builds.
    pub fn absorb(&self, tenant: TenantId, other: &ArtifactCache) {
        let mut src = other.guard();
        let entries = std::mem::take(&mut src.entries);
        let (builds, hits) = (src.builds, src.hits);
        src.builds = 0;
        src.hits = 0;
        drop(src);
        let mut inner = self.guard();
        inner.builds += builds;
        inner.hits += hits;
        let t = inner.tenant(tenant);
        t.builds += builds;
        t.hits += hits;
        for ((_, bits, n), art) in entries {
            let key = (tenant, bits, n);
            if let Some(pos) = inner.entries.iter().position(|(k, _)| *k == key) {
                inner.entries.remove(pos);
            } else if inner.entries.len() >= self.cap {
                inner.evict_front();
            }
            inner.entries.push((key, art));
        }
    }

    /// Snapshots built so far, all tenants (telemetry / regression tests).
    pub fn builds(&self) -> u64 {
        self.guard().builds
    }

    /// Cache hits so far, all tenants.
    pub fn hits(&self) -> u64 {
        self.guard().hits
    }

    /// LRU evictions so far, all tenants.
    pub fn evictions(&self) -> u64 {
        self.guard().evictions
    }

    /// One tenant's build / hit / eviction counters.
    pub fn tenant_stats(&self, tenant: TenantId) -> TenantCacheStats {
        self.guard().per_tenant.get(&tenant).copied().unwrap_or_default()
    }

    /// Live entries, all tenants.
    pub fn len(&self) -> usize {
        self.guard().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Live entries belonging to `tenant`.
    pub fn len_for(&self, tenant: TenantId) -> usize {
        self.guard().entries.iter().filter(|(k, _)| k.0 == tenant).count()
    }

    /// The capacity bound (entries never exceed it).
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn dummy_artifact(tag: f64) -> Arc<PosteriorArtifact> {
        Arc::new(PosteriorArtifact {
            theta: vec![tag],
            n: 1,
            vy: vec![tag],
            zhat: Mat::zeros(1, 1),
            omega0: Mat::zeros(1, 1),
            wts: Mat::zeros(2, 1),
            noise_var: 0.0,
        })
    }

    fn hp(sigma: f64) -> Hyperparams {
        Hyperparams { ell: vec![1.0, 2.0], sigf: 1.0, sigma }
    }

    #[test]
    fn cache_hits_on_same_key_and_misses_on_changes() {
        let cache = ArtifactCache::default();
        assert!(cache.get(0, &hp(0.3), 10).is_none());
        cache.insert(0, &hp(0.3), 10, dummy_artifact(1.0));
        assert_eq!(cache.builds(), 1);
        let a = cache.get(0, &hp(0.3), 10).expect("hit");
        assert_eq!(a.theta, vec![1.0]);
        assert_eq!(cache.hits(), 1);
        // tenant, hyperparameter bits and n are all part of the key
        assert!(cache.get(1, &hp(0.3), 10).is_none());
        assert!(cache.get(0, &hp(0.31), 10).is_none());
        assert!(cache.get(0, &hp(0.3), 11).is_none());
    }

    #[test]
    fn cache_replaces_same_key_and_evicts_lru() {
        let cache = ArtifactCache::with_capacity(2);
        cache.insert(0, &hp(0.1), 5, dummy_artifact(1.0));
        cache.insert(0, &hp(0.1), 5, dummy_artifact(2.0)); // replace, not grow
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.get(0, &hp(0.1), 5).unwrap().theta, vec![2.0]);
        cache.insert(0, &hp(0.2), 5, dummy_artifact(3.0));
        // touch 0.1 so 0.2 becomes the LRU victim of the next insert
        let _ = cache.get(0, &hp(0.1), 5);
        cache.insert(0, &hp(0.3), 5, dummy_artifact(4.0));
        assert!(cache.get(0, &hp(0.2), 5).is_none());
        assert!(cache.get(0, &hp(0.1), 5).is_some());
        assert!(cache.get(0, &hp(0.3), 5).is_some());
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn cache_invalidate_keeps_counters() {
        let cache = ArtifactCache::default();
        cache.insert(0, &hp(0.1), 5, dummy_artifact(1.0));
        let _ = cache.get(0, &hp(0.1), 5);
        cache.invalidate_all();
        assert!(cache.is_empty());
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 1);
        assert!(cache.get(0, &hp(0.1), 5).is_none());
    }

    #[test]
    fn invalidate_tenant_spares_the_other_tenants() {
        let cache = ArtifactCache::with_capacity(4);
        cache.insert(1, &hp(0.1), 5, dummy_artifact(1.0));
        cache.insert(2, &hp(0.1), 5, dummy_artifact(2.0));
        cache.insert(2, &hp(0.2), 5, dummy_artifact(3.0));
        cache.invalidate_tenant(2);
        assert_eq!(cache.len(), 1);
        assert!(cache.get(1, &hp(0.1), 5).is_some(), "tenant 1 must survive");
        assert!(cache.get(2, &hp(0.1), 5).is_none());
    }

    #[test]
    fn per_tenant_counters_attribute_evictions_to_the_victim() {
        let cache = ArtifactCache::with_capacity(2);
        cache.insert(1, &hp(0.1), 5, dummy_artifact(1.0));
        cache.insert(2, &hp(0.2), 5, dummy_artifact(2.0));
        let _ = cache.get(2, &hp(0.2), 5);
        // tenant 3's insert evicts tenant 1's LRU entry
        cache.insert(3, &hp(0.3), 5, dummy_artifact(3.0));
        assert_eq!(cache.tenant_stats(1), TenantCacheStats { builds: 1, hits: 0, evictions: 1 });
        assert_eq!(cache.tenant_stats(2), TenantCacheStats { builds: 1, hits: 1, evictions: 0 });
        assert_eq!(cache.tenant_stats(3), TenantCacheStats { builds: 1, hits: 0, evictions: 0 });
        assert_eq!(cache.builds(), 3);
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len_for(1), 0);
        assert_eq!(cache.len_for(2), 1);
    }

    #[test]
    fn absorb_migrates_entries_and_counters_without_recounting_builds() {
        let private = ArtifactCache::with_capacity(2);
        private.insert(0, &hp(0.1), 5, dummy_artifact(1.0));
        let _ = private.get(0, &hp(0.1), 5);
        let shared = ArtifactCache::shared_with_capacity(3);
        shared.insert(1, &hp(0.9), 9, dummy_artifact(9.0));
        shared.absorb(7, &private);
        // the entry moved under tenant 7 and serves without a rebuild
        assert!(shared.get(7, &hp(0.1), 5).is_some());
        assert!(private.is_empty(), "absorb must drain the source");
        // counters transferred, not re-counted: 1 migrated + 1 native build
        assert_eq!(shared.builds(), 2);
        assert_eq!(shared.tenant_stats(7).builds, 1);
        assert_eq!(shared.tenant_stats(7).hits, 2); // 1 migrated + the get above
        assert_eq!(private.builds(), 0);
    }
}
