//! Serving observability: deterministic throughput counters plus a
//! fixed-bucket latency histogram.
//!
//! The counters ([`ServeCounters`]) are exactly reproducible — property
//! tests compare them against a reference model with `==` — so they live
//! apart from the wall-clock measurements ([`LatencyHistogram`], serve
//! seconds), which are monotone but not reproducible.  The histogram uses
//! power-of-two microsecond buckets (bucket i counts latencies below
//! `2^i` µs, the last bucket is the overflow): 24 fixed buckets cover
//! 1 µs .. ~4 s with zero allocation and O(1) recording, and quantiles
//! read as bucket upper bounds — a conservative (never-understating)
//! p50/p99, the convention of fixed-bucket production histograms.

/// Number of histogram buckets.  Bucket `i < LATENCY_BUCKETS - 1` counts
/// latencies in `[2^(i-1), 2^i)` µs (bucket 0: `[0, 1)` µs); the final
/// bucket counts everything at or above `2^(LATENCY_BUCKETS-2)` µs (~4 s).
pub const LATENCY_BUCKETS: usize = 24;

/// Fixed-bucket enqueue→answer latency histogram (power-of-two µs bounds).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyHistogram {
    counts: [u64; LATENCY_BUCKETS],
    count: u64,
    total_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; LATENCY_BUCKETS], count: 0, total_ns: 0, max_ns: 0 }
    }
}

impl LatencyHistogram {
    /// The bucket a latency of `ns` nanoseconds falls into.
    fn bucket(ns: u64) -> usize {
        let mut i = 0;
        while i < LATENCY_BUCKETS - 1 && ns >= Self::upper_bound_ns(i) {
            i += 1;
        }
        i
    }

    /// Exclusive upper bound of bucket `i` in ns (`2^i` µs); the overflow
    /// bucket has no bound.
    pub fn upper_bound_ns(i: usize) -> u64 {
        1000u64 << i
    }

    /// Record one request's latency.
    pub fn record(&mut self, ns: u64) {
        self.counts[Self::bucket(ns)] += 1;
        self.count += 1;
        self.total_ns = self.total_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Recorded requests.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Per-bucket counts (telemetry emission).
    pub fn buckets(&self) -> &[u64; LATENCY_BUCKETS] {
        &self.counts
    }

    /// Largest recorded latency.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Mean latency in ns (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 { 0 } else { self.total_ns / self.count }
    }

    /// The `q`-quantile in ns, reported as the containing bucket's upper
    /// bound (capped at the observed maximum, so an all-in-one-bucket
    /// histogram never overstates past its own max).  0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i == LATENCY_BUCKETS - 1 {
                    self.max_ns
                } else {
                    Self::upper_bound_ns(i).min(self.max_ns)
                };
            }
        }
        self.max_ns
    }

    pub fn p50_ns(&self) -> u64 {
        self.quantile_ns(0.50)
    }

    pub fn p99_ns(&self) -> u64 {
        self.quantile_ns(0.99)
    }
}

/// Deterministic throughput / cache / policy counters of one service —
/// exactly reproducible for a given op sequence, so property tests model
/// them with plain `==`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeCounters {
    /// Query rows answered.
    pub rows_served: u64,
    /// Evaluation blocks actually executed at the backend — counted at the
    /// execution site, so a backend that coalesces a request into one
    /// internally-parallel pass (tiled/sharded) counts 1 where the generic
    /// block fan-out (dense) counts ceil(rows / batch).
    pub batches: u64,
    /// Posterior snapshots built for this tenant over the trainer's life.
    pub artifact_builds: u64,
    /// Snapshot cache hits for this tenant over the trainer's life.
    pub artifact_hits: u64,
    /// This tenant's snapshots evicted by shared-cache LRU pressure.
    pub artifact_evictions: u64,
    /// Rows answered from a marked-stale snapshot (`serve_stale` policy).
    pub stale_rows_served: u64,
    /// Requests rejected at admission (queue cap) or by the `refuse`
    /// staleness policy.
    pub rejected: u64,
    /// Rows answered *degraded*: a `refresh_first` refresh failed and the
    /// service downgraded to the retained stale snapshot instead of
    /// erroring (always ≤ `stale_rows_served`).
    pub degraded_rows_served: u64,
}

/// Full observability snapshot: the deterministic counters plus the
/// latency histogram and total serve wall time.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub counters: ServeCounters,
    /// Per-request enqueue→answer latency (one-shot `predict` records its
    /// serve wall time — enqueue and answer coincide).
    pub latency: LatencyHistogram,
    /// Wall nanoseconds inside backend evaluation across every serve.
    pub serve_ns: u64,
}

impl ServeStats {
    pub fn p50_ns(&self) -> u64 {
        self.latency.p50_ns()
    }

    pub fn p99_ns(&self) -> u64 {
        self.latency.p99_ns()
    }

    /// Serving throughput: rows answered per second of backend evaluation
    /// wall time (0 when nothing was served).
    pub fn rows_per_sec(&self) -> f64 {
        if self.serve_ns == 0 {
            0.0
        } else {
            self.counters.rows_served as f64 / (self.serve_ns as f64 * 1e-9)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_power_of_two_microseconds() {
        assert_eq!(LatencyHistogram::bucket(0), 0);
        assert_eq!(LatencyHistogram::bucket(999), 0); // < 1 µs
        assert_eq!(LatencyHistogram::bucket(1000), 1); // [1, 2) µs
        assert_eq!(LatencyHistogram::bucket(1999), 1);
        assert_eq!(LatencyHistogram::bucket(2000), 2);
        assert_eq!(LatencyHistogram::bucket(1_000_000), 10); // 1 ms -> [512, 1024) µs
        assert_eq!(LatencyHistogram::upper_bound_ns(10), 1_024_000);
        // the overflow bucket swallows anything huge
        assert_eq!(LatencyHistogram::bucket(u64::MAX), LATENCY_BUCKETS - 1);
    }

    #[test]
    fn quantiles_read_bucket_upper_bounds_capped_at_max() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.p50_ns(), 0);
        for _ in 0..99 {
            h.record(1500); // bucket 1, bound 2000
        }
        h.record(5_000_000); // one slow request
        assert_eq!(h.count(), 100);
        assert_eq!(h.p50_ns(), 2000);
        assert_eq!(h.p99_ns(), 2000);
        assert_eq!(h.quantile_ns(1.0), 5_000_000); // bucket bound capped at max
        assert_eq!(h.max_ns(), 5_000_000);
        // a single-sample histogram reports its own value at every quantile
        let mut one = LatencyHistogram::default();
        one.record(700);
        assert_eq!(one.p50_ns(), 700); // bound 1000 capped at max 700
        assert_eq!(one.p99_ns(), 700);
    }

    #[test]
    fn rows_per_sec_uses_serve_wall_time() {
        let mut st = ServeStats::default();
        assert_eq!(st.rows_per_sec(), 0.0);
        st.counters.rows_served = 500;
        st.serve_ns = 250_000_000; // 0.25 s
        assert!((st.rows_per_sec() - 2000.0).abs() < 1e-9);
    }
}
