//! Minimal in-tree stand-in for the `anyhow` crate, implementing exactly the
//! API surface this repository uses: [`Error`], [`Result`], the [`Context`]
//! extension trait and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Vendored because the build environment is offline (no crates.io access);
//! see the workspace `Cargo.toml`.  The semantics mirror upstream anyhow for
//! the used subset: an error is a chain of context messages, `Display`
//! prints the outermost message, alternate `Display` (`{:#}`) and `Debug`
//! print the full `outer: ... : root-cause` chain, and any
//! `std::error::Error + Send + Sync + 'static` converts into [`Error`] via
//! `?`.

use std::fmt;

/// A context-chain error value.  Most-recent context first.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msgs: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// The full `outer: ... : root` chain as a single string.
    pub fn chain_string(&self) -> String {
        self.msgs.join(": ")
    }

    /// The root-cause (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain_string())
        } else {
            f.write_str(self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain_string())
    }
}

// Mirrors upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion coherent
// alongside the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_context() {
        let r: Result<()> = Err(io_err()).context("reading file");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert!(format!("{e:#}").contains("gone"));
    }

    #[test]
    fn with_context_lazily_formats() {
        let name = "x";
        let r: Result<()> = Err(io_err()).with_context(|| format!("loading {name}"));
        assert_eq!(r.unwrap_err().to_string(), "loading x");
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        fn inner() -> Result<()> {
            bail!("root cause {}", 7);
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause(), "root cause 7");
        assert_eq!(format!("{e:#}"), "outer: root cause 7");
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 3;
        let b = anyhow!("value {x} and {}", 4);
        assert_eq!(b.to_string(), "value 3 and 4");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn ensure_returns_err_on_false() {
        fn check(v: i32) -> Result<i32> {
            ensure!(v > 0, "need positive, got {v}");
            Ok(v)
        }
        assert_eq!(check(2).unwrap(), 2);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn question_mark_converts() {
        fn parse(s: &str) -> Result<i64> {
            let v: i64 = s.parse()?;
            Ok(v)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
